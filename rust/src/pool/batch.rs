//! Batched request execution with overlap scheduling — the serve-path
//! realisation of E18's model (§3.1, §8).
//!
//! The executor admits a queue of addressed requests and groups
//! compatible work: all SQL against one table shares compare passes
//! through [`crate::sql::Table::query_batch`]'s per-batch query memo;
//! identical searches against one corpus share one broadcast pass.
//! Each group is charged as
//! one (load, exec) phase — exclusive-bus ops load, concurrent macro
//! cycles execute — and the phase list is scheduled with
//! [`OverlapScheduler`], so the exclusive/concurrent overlap finally
//! drives real serving instead of a standalone model.
//!
//! Correctness: corpus edits (`Insert`/`Delete`/`Replace`) are barriers —
//! a search group never spans an edit of its own corpus, and groups run
//! in first-member order — so batched responses are identical to
//! one-at-a-time serving of the same queue (pinned by
//! `tests/pool_props.rs`).

use std::collections::BTreeMap;
use std::time::Instant;

use crate::algos::{histogram, reduce, sort, threshold};
use crate::coordinator::scheduler::{OverlapScheduler, PlacedTask, TaskPhase};
use crate::coordinator::server::{default_device, Addressed, ArrayJob, Request, Response};
use crate::cycles::ConcurrentCost;
use crate::device::computable::{ExecConfig, PePlane, Reg, WordExec};
use crate::error::{CpmError, Result};
use crate::sql::Query;

use super::allocator::{missing, wrong_kind, DevicePool};

/// What one executed batch cost, group by group.
#[derive(Debug, Default, Clone)]
pub struct BatchReport {
    /// One (load, exec) phase per executed group, in execution order.
    pub phases: Vec<TaskPhase>,
    /// One placed task per executed group — the phase plus the home
    /// plane of the group's resident device and its cross-plane move
    /// cost — feeding the multi-plane schedulers. Ad-hoc compute has no
    /// home and moves for free.
    pub placed: Vec<PlacedTask>,
    /// Device cost per group, attributed to the group's tenant.
    pub group_costs: Vec<(String, ConcurrentCost)>,
    /// Device passes avoided by sharing compare/search passes.
    pub shared_passes: u64,
    /// Groups executed.
    pub groups: u64,
    /// Makespan if the grouped phases ran back-to-back (no overlap).
    pub makespan_serial: u64,
    /// Makespan with task k+1's exclusive-bus load streamed while task k
    /// executes on the concurrent bus (§3.1).
    pub makespan_overlapped: u64,
    /// Makespan with the grouped phases placed across the pool's PE
    /// planes ([`OverlapScheduler::makespan_multi`]); equals
    /// `makespan_overlapped` on a single-plane pool.
    pub makespan_multi: u64,
    /// `makespan_multi` recomputed with the §8 DMA side bus carrying
    /// load phases ([`ExecConfig::dma_speedup`]); equals
    /// `makespan_multi` when the side bus is off.
    pub makespan_dma: u64,
    /// Wall nanoseconds the planner spent forming the groups (the
    /// observability layer's `group_plan_ns` counter).
    pub plan_ns: u64,
}

/// Borrowed view of an [`Addressed`] request. The executor works on
/// these so the serve path never clones request payloads — the owned
/// envelope is only for callers that store or send requests.
#[derive(Debug, Clone, Copy)]
pub struct AddressedRef<'a> {
    /// Owning tenant.
    pub tenant: &'a str,
    /// Explicit target device name, if any.
    pub device: Option<&'a str>,
    /// The operation.
    pub op: &'a Request,
}

impl<'a> From<&'a Addressed> for AddressedRef<'a> {
    fn from(a: &'a Addressed) -> Self {
        AddressedRef {
            tenant: &a.tenant,
            device: a.device.as_deref(),
            op: &a.op,
        }
    }
}

impl<'a> AddressedRef<'a> {
    /// The resident device this request targets (see
    /// [`Addressed::device_name`]).
    pub fn device_name(&self) -> &'a str {
        match self.device {
            Some(d) => d,
            None => default_device(self.op),
        }
    }
}

/// Groups, executes, and overlap-schedules a queue of requests against a
/// [`DevicePool`].
#[derive(Debug, Clone)]
pub struct BatchExecutor {
    /// Largest ad-hoc array a computable-memory job may load.
    engine_capacity: usize,
    /// Plane-execution policy for computable-memory work: every ad-hoc
    /// plane is constructed through the config's
    /// [`ComputeBackend`](crate::device::computable::ComputeBackend)
    /// (`backend` selects the executor, `threads = 1` is the serial
    /// engines). The config carries the server's persistent worker-pool
    /// handle, so every request's plane dispatches onto the same parked
    /// workers for the process lifetime.
    exec: ExecConfig,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum GroupKind {
    Sql,
    Search,
    Solo,
}

#[derive(Debug)]
struct Group {
    kind: GroupKind,
    tenant: String,
    device: String,
    members: Vec<usize>,
}

/// Append member `i` to the open group under `key`, creating the group
/// first if none is open.
fn open_group(
    groups: &mut Vec<Group>,
    open: &mut BTreeMap<(String, String), usize>,
    kind: GroupKind,
    key: (String, String),
    i: usize,
) {
    let gid = match open.get(&key) {
        Some(&g) => g,
        None => {
            groups.push(Group {
                kind,
                tenant: key.0.clone(),
                device: key.1.clone(),
                members: Vec::new(),
            });
            let g = groups.len() - 1;
            open.insert(key, g);
            g
        }
    };
    groups[gid].members.push(i);
}

/// Partition the batch into groups. SQL requests group per
/// `(tenant, table)` for the whole batch (no request mutates a table);
/// searches group per `(tenant, corpus)` *between edits of that corpus*;
/// everything else runs solo in arrival order.
fn plan(batch: &[AddressedRef<'_>]) -> Vec<Group> {
    let mut groups: Vec<Group> = Vec::new();
    let mut open_sql: BTreeMap<(String, String), usize> = BTreeMap::new();
    let mut open_search: BTreeMap<(String, String), usize> = BTreeMap::new();
    for (i, a) in batch.iter().enumerate() {
        let key = (a.tenant.to_string(), a.device_name().to_string());
        match a.op {
            Request::Sql(_) => {
                open_group(&mut groups, &mut open_sql, GroupKind::Sql, key, i);
            }
            Request::Search(_) => {
                open_group(&mut groups, &mut open_search, GroupKind::Search, key, i);
            }
            _ => {
                if matches!(
                    a.op,
                    Request::Insert(..) | Request::Delete(..) | Request::Replace(..)
                ) {
                    // Barrier: later searches on this corpus open a new
                    // group.
                    open_search.remove(&key);
                }
                groups.push(Group {
                    kind: GroupKind::Solo,
                    tenant: key.0,
                    device: key.1,
                    members: vec![i],
                });
            }
        }
    }
    groups
}

/// Record one executed group: its (load, exec) phase, its placement
/// (home plane + move cost for groups on a resident device, ad-hoc
/// otherwise), and its tenant-attributed cost.
fn push_phase(
    report: &mut BatchReport,
    tenant: &str,
    cost: ConcurrentCost,
    placement: Option<(usize, u64)>,
) {
    let phase = TaskPhase {
        load_cycles: cost.exclusive_ops,
        exec_cycles: cost.macro_cycles,
    };
    report.phases.push(phase);
    report.placed.push(match placement {
        Some((home, move_cycles)) => PlacedTask {
            phase,
            home: Some(home),
            move_cycles,
        },
        None => PlacedTask::adhoc(phase),
    });
    report.group_costs.push((tenant.to_string(), cost));
}

impl BatchExecutor {
    /// Executor with the given ad-hoc computable-memory capacity and
    /// serial plane execution.
    pub fn new(engine_capacity: usize) -> Self {
        BatchExecutor::with_exec(engine_capacity, ExecConfig::default())
    }

    /// Executor with an explicit plane-execution policy.
    pub fn with_exec(engine_capacity: usize, exec: ExecConfig) -> Self {
        BatchExecutor {
            engine_capacity,
            exec,
        }
    }

    /// Change the plane-execution policy (e.g. the CLI `--threads` flag).
    pub fn set_exec(&mut self, exec: ExecConfig) {
        self.exec = exec;
    }

    /// The plane-execution policy in force (gauge sampling reads the
    /// worker-pool handle through this).
    pub fn exec(&self) -> &ExecConfig {
        &self.exec
    }

    /// Execute a batch. Responses align with `batch` order; the report
    /// carries the per-group phases, costs, and makespans.
    pub fn execute(
        &self,
        pool: &mut DevicePool,
        batch: &[AddressedRef<'_>],
    ) -> (Vec<Result<Response>>, BatchReport) {
        let plan_start = Instant::now();
        let groups = plan(batch);
        let mut responses: Vec<Option<Result<Response>>> =
            (0..batch.len()).map(|_| None).collect();
        let mut report = BatchReport {
            plan_ns: plan_start.elapsed().as_nanos() as u64,
            ..BatchReport::default()
        };
        for g in &groups {
            match g.kind {
                GroupKind::Sql => self.run_sql_group(pool, g, batch, &mut responses, &mut report),
                GroupKind::Search => {
                    self.run_search_group(pool, g, batch, &mut responses, &mut report)
                }
                GroupKind::Solo => {
                    let i = g.members[0];
                    let (resp, cost) =
                        self.dispatch_solo(pool, &g.tenant, &g.device, batch[i].op);
                    responses[i] = Some(resp);
                    // Resident devices (corpus edits, array jobs) carry
                    // their home plane; ad-hoc compute is unplaced.
                    let placement = pool.placement_of(&g.tenant, &g.device);
                    push_phase(&mut report, &g.tenant, cost, placement);
                }
            }
        }
        report.groups = groups.len() as u64;
        report.makespan_serial = OverlapScheduler::makespan_serial(&report.phases);
        report.makespan_overlapped = OverlapScheduler::makespan_overlapped(&report.phases);
        report.makespan_multi =
            OverlapScheduler::makespan_multi(&report.placed, pool.plane_count());
        report.makespan_dma = OverlapScheduler::makespan_multi_with_dma(
            &report.placed,
            pool.plane_count(),
            self.exec.dma_speedup,
        );
        let responses = responses
            .into_iter()
            .map(|r| r.expect("every request answered"))
            .collect();
        (responses, report)
    }

    fn run_sql_group(
        &self,
        pool: &mut DevicePool,
        g: &Group,
        batch: &[AddressedRef<'_>],
        responses: &mut [Option<Result<Response>>],
        report: &mut BatchReport,
    ) {
        // Parse first: malformed queries answer without touching devices.
        let mut queries = Vec::new();
        let mut slots = Vec::new();
        for &i in &g.members {
            if let Request::Sql(text) = batch[i].op {
                match Query::parse(text) {
                    Ok(q) => {
                        queries.push(q);
                        slots.push(i);
                    }
                    Err(e) => responses[i] = Some(Err(e)),
                }
            }
        }
        match pool.kind_of(&g.tenant, &g.device) {
            Some("table") => {}
            // Same typed errors table_mut would produce, one per member.
            kind => {
                for &i in &slots {
                    responses[i] = Some(Err(match kind {
                        None => missing(&g.tenant, &g.device),
                        Some(k) => wrong_kind(&g.tenant, &g.device, k, "table"),
                    }));
                }
                return;
            }
        }
        let table = pool
            .table_mut(&g.tenant, &g.device)
            .expect("probed just above");
        table.reset_device_cost();
        let (results, stats) = table.query_batch(&queries);
        let cost = table.device_cost();
        for (r, &i) in results.into_iter().zip(&slots) {
            responses[i] = Some(r.map(Response::Sql));
        }
        report.shared_passes += stats.shared_passes();
        let placement = pool.placement_of(&g.tenant, &g.device);
        push_phase(report, &g.tenant, cost, placement);
    }

    fn run_search_group(
        &self,
        pool: &mut DevicePool,
        g: &Group,
        batch: &[AddressedRef<'_>],
        responses: &mut [Option<Result<Response>>],
        report: &mut BatchReport,
    ) {
        match pool.kind_of(&g.tenant, &g.device) {
            Some("corpus") => {}
            // Same typed errors corpus_mut would produce, one per member.
            kind => {
                for &i in &g.members {
                    responses[i] = Some(Err(match kind {
                        None => missing(&g.tenant, &g.device),
                        Some(k) => wrong_kind(&g.tenant, &g.device, k, "corpus"),
                    }));
                }
                return;
            }
        }
        let corpus = pool
            .corpus_mut(&g.tenant, &g.device)
            .expect("probed just above");
        corpus.reset_cost();
        // Identical patterns share one ~M-cycle broadcast pass: the first
        // occurrence drives the match ladder, duplicates read the same
        // match lines.
        let mut cache: BTreeMap<&[u8], Vec<usize>> = BTreeMap::new();
        for &i in &g.members {
            if let Request::Search(pattern) = batch[i].op {
                let hits = match cache.get(pattern.as_slice()) {
                    Some(h) => {
                        report.shared_passes += 1;
                        h.clone()
                    }
                    None => {
                        let h = corpus.find(pattern);
                        cache.insert(pattern.as_slice(), h.clone());
                        h
                    }
                };
                responses[i] = Some(Ok(Response::Matches(hits)));
            }
        }
        let cost = corpus.cost();
        let placement = pool.placement_of(&g.tenant, &g.device);
        push_phase(report, &g.tenant, cost, placement);
    }

    /// Execute one non-groupable request (corpus edits, ad-hoc compute,
    /// resident-array jobs).
    fn dispatch_solo(
        &self,
        pool: &mut DevicePool,
        tenant: &str,
        device: &str,
        op: &Request,
    ) -> (Result<Response>, ConcurrentCost) {
        match op {
            // plan() routes every Sql/Search into a (possibly 1-member)
            // group; keeping a second execution path here would be dead
            // code that could silently diverge from the group runners.
            Request::Sql(_) | Request::Search(_) => {
                unreachable!("Sql/Search always execute through their group runners")
            }
            Request::Insert(at, data) => match pool.corpus_mut(tenant, device) {
                Ok(corpus) => {
                    corpus.reset_cost();
                    // The device itself rejects growth past its PE count
                    // with a typed CapacityExceeded before anything moves.
                    let r = corpus
                        .insert(*at, data)
                        .map(|()| Response::Scalar(corpus.len() as i64));
                    (r, corpus.cost())
                }
                Err(e) => (Err(e), ConcurrentCost::default()),
            },
            Request::Delete(at, len) => match pool.corpus_mut(tenant, device) {
                Ok(corpus) => {
                    corpus.reset_cost();
                    let r = corpus
                        .delete(*at, *len)
                        .map(|()| Response::Scalar(corpus.len() as i64));
                    (r, corpus.cost())
                }
                Err(e) => (Err(e), ConcurrentCost::default()),
            },
            Request::Replace(pattern, replacement) => match pool.corpus_mut(tenant, device) {
                Ok(corpus) => {
                    corpus.reset_cost();
                    let r = corpus
                        .replace_all(pattern, replacement)
                        .map(|n| Response::Scalar(n as i64));
                    (r, corpus.cost())
                }
                Err(e) => (Err(e), ConcurrentCost::default()),
            },
            Request::Sum(values) => match self.engine_for(values) {
                Ok(mut e) => {
                    let run = reduce::sum_1d_opt(&mut e, values.len());
                    (Ok(Response::Scalar(run.value)), e.cost())
                }
                Err(e) => (Err(e), ConcurrentCost::default()),
            },
            Request::Max(values) => {
                if values.is_empty() {
                    return (
                        Err(CpmError::Coordinator("max of empty array".into())),
                        ConcurrentCost::default(),
                    );
                }
                match self.engine_for(values) {
                    Ok(mut e) => {
                        let m = crate::util::isqrt(values.len() as u64).max(1) as usize;
                        let run = reduce::max_1d(&mut e, values.len(), m);
                        (Ok(Response::Scalar(run.value as i64)), e.cost())
                    }
                    Err(e) => (Err(e), ConcurrentCost::default()),
                }
            }
            Request::Sort(values) => match self.engine_for(values) {
                Ok(mut e) => {
                    sort::sort_sqrt(&mut e, values.len());
                    let sorted = e.plane(Reg::Nb)[..values.len()].to_vec();
                    (Ok(Response::Sorted(sorted)), e.cost())
                }
                Err(e) => (Err(e), ConcurrentCost::default()),
            },
            Request::Threshold(values, t) => match self.engine_for(values) {
                Ok(mut e) => {
                    let count = threshold::threshold_mark(&mut e, values.len(), *t);
                    (Ok(Response::Scalar(count as i64)), e.cost())
                }
                Err(e) => (Err(e), ConcurrentCost::default()),
            },
            Request::Histogram(values, bounds) => match self.engine_for(values) {
                Ok(mut e) => {
                    let counts = histogram::histogram_words(&mut e, values.len(), bounds);
                    (Ok(Response::Histogram(counts)), e.cost())
                }
                Err(e) => (Err(e), ConcurrentCost::default()),
            },
            Request::Array(job) => self.run_array_job(pool, tenant, device, job),
        }
    }

    fn run_array_job(
        &self,
        pool: &mut DevicePool,
        tenant: &str,
        device: &str,
        job: &ArrayJob,
    ) -> (Result<Response>, ConcurrentCost) {
        let values = match pool.array_mut(tenant, device) {
            Ok(a) => a.values().to_vec(),
            Err(e) => return (Err(e), ConcurrentCost::default()),
        };
        let n = values.len();
        let mut e = self.exec.compute_backend().word_plane(n.max(1), 16);
        e.load_plane(Reg::Nb, &values);
        // The array is resident in the PE plane between jobs: its load was
        // paid at admission, so a job charges execution cycles only.
        e.reset_cost();
        let r = match job {
            ArrayJob::Sum => Response::Scalar(reduce::sum_1d_opt(&mut e, n).value),
            ArrayJob::Max => {
                if values.is_empty() {
                    return (
                        Err(CpmError::Coordinator("max of empty array".into())),
                        ConcurrentCost::default(),
                    );
                }
                let m = crate::util::isqrt(n as u64).max(1) as usize;
                Response::Scalar(reduce::max_1d(&mut e, n, m).value as i64)
            }
            ArrayJob::Sort => {
                sort::sort_sqrt(&mut e, n);
                Response::Sorted(e.plane(Reg::Nb)[..n].to_vec())
            }
            ArrayJob::Threshold(t) => {
                Response::Scalar(threshold::threshold_mark(&mut e, n, *t) as i64)
            }
            ArrayJob::Histogram(bounds) => {
                Response::Histogram(histogram::histogram_words(&mut e, n, bounds))
            }
        };
        (Ok(r), e.cost())
    }

    fn engine_for(&self, values: &[i32]) -> Result<Box<dyn WordExec>> {
        if values.len() > self.engine_capacity {
            return Err(CpmError::Coordinator(format!(
                "array of {} exceeds device capacity {}",
                values.len(),
                self.engine_capacity
            )));
        }
        let mut e = self.exec.compute_backend().word_plane(values.len().max(1), 16);
        e.load_plane(Reg::Nb, values);
        Ok(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{DEFAULT_CORPUS, DEFAULT_TABLE, DEFAULT_TENANT};
    use crate::pool::PoolConfig;
    use crate::sql::Schema;

    fn pool_with_defaults() -> DevicePool {
        let mut pool = DevicePool::new(PoolConfig {
            capacity_pes: 1 << 16,
            tenant_quota_pes: 1 << 16,
            corpus_slack: 64,
            ..PoolConfig::default()
        });
        let schema = Schema::new(&[("price", 2), ("qty", 1)]).unwrap();
        pool.create_table(DEFAULT_TENANT, DEFAULT_TABLE, schema, 64)
            .unwrap();
        pool.create_corpus(DEFAULT_TENANT, DEFAULT_CORPUS, b"abc abc abc")
            .unwrap();
        let table = pool.table_mut(DEFAULT_TENANT, DEFAULT_TABLE).unwrap();
        for row in [[100u64, 1], [2500, 2], [9000, 3], [400, 4]] {
            table.insert(&row).unwrap();
        }
        pool
    }

    fn local(op: Request) -> Addressed {
        Addressed::local(op)
    }

    fn refs(batch: &[Addressed]) -> Vec<AddressedRef<'_>> {
        batch.iter().map(AddressedRef::from).collect()
    }

    #[test]
    fn grouping_respects_corpus_edit_barriers() {
        let batch = vec![
            local(Request::Search(b"abc".to_vec())),
            local(Request::Sql("SELECT COUNT WHERE price < 1000".into())),
            local(Request::Search(b"abc".to_vec())),
            local(Request::Insert(0, b"x".to_vec())),
            local(Request::Search(b"abc".to_vec())),
            local(Request::Sql("SELECT COUNT WHERE price < 1000".into())),
        ];
        let groups = plan(&refs(&batch));
        // search{0,2} | sql{1,5} | insert{3} | search{4}
        assert_eq!(groups.len(), 4);
        assert_eq!(groups[0].members, vec![0, 2]);
        assert_eq!(groups[0].kind, GroupKind::Search);
        assert_eq!(groups[1].members, vec![1, 5]);
        assert_eq!(groups[1].kind, GroupKind::Sql);
        assert_eq!(groups[2].members, vec![3]);
        assert_eq!(groups[3].members, vec![4]);
    }

    #[test]
    fn batch_answers_every_request_in_order() {
        let mut pool = pool_with_defaults();
        let ex = BatchExecutor::new(1 << 12);
        let batch = vec![
            local(Request::Sql("SELECT COUNT WHERE price < 1000".into())),
            local(Request::Search(b"abc".to_vec())),
            local(Request::Search(b"abc".to_vec())),
            local(Request::Sum(vec![1, 2, 3, 4])),
            local(Request::Sql("garbage".into())),
        ];
        let (responses, report) = ex.execute(&mut pool, &refs(&batch));
        assert_eq!(responses.len(), 5);
        assert_eq!(
            responses[0].as_ref().unwrap(),
            &Response::Sql(crate::sql::QueryResult::Count(2))
        );
        assert_eq!(
            responses[1].as_ref().unwrap(),
            &Response::Matches(vec![2, 6, 10])
        );
        assert_eq!(responses[1].as_ref().unwrap(), responses[2].as_ref().unwrap());
        assert_eq!(responses[3].as_ref().unwrap(), &Response::Scalar(10));
        assert!(responses[4].is_err());
        // Duplicate search shares the broadcast pass.
        assert_eq!(report.shared_passes, 1);
        assert!(report.makespan_overlapped <= report.makespan_serial);
        assert!(report.groups >= 3);
        // Every phase got a placement record; on the default single-plane
        // pool with DMA off, the multi-plane and DMA makespans collapse
        // onto the overlapped one exactly.
        assert_eq!(report.placed.len(), report.phases.len());
        assert_eq!(report.makespan_multi, report.makespan_overlapped);
        assert_eq!(report.makespan_dma, report.makespan_multi);
    }

    #[test]
    fn multi_plane_report_places_groups_on_their_home_planes() {
        let mut pool = DevicePool::new(PoolConfig {
            capacity_pes: 1 << 16,
            tenant_quota_pes: 1 << 16,
            corpus_slack: 64,
            planes: 2,
            ..PoolConfig::default()
        });
        pool.create_corpus("a", "corpus", b"abc abc").unwrap();
        pool.create_corpus("b", "corpus", b"xyz xyz").unwrap();
        let ex = BatchExecutor::new(1 << 12);
        let batch = vec![
            Addressed::new("a", "corpus", Request::Search(b"abc".to_vec())),
            Addressed::new("b", "corpus", Request::Search(b"xyz".to_vec())),
        ];
        let (responses, report) = ex.execute(&mut pool, &refs(&batch));
        assert!(responses.iter().all(|r| r.is_ok()));
        // Worst-fit placement spread the two corpora across the planes,
        // and the report records each group's home.
        let homes: Vec<_> = report.placed.iter().map(|p| p.home).collect();
        assert_eq!(homes, vec![Some(0), Some(1)]);
        assert!(report.makespan_multi <= report.makespan_overlapped);
        assert_eq!(report.makespan_dma, report.makespan_multi);
    }

    #[test]
    fn missing_devices_answer_typed_errors() {
        let mut pool = pool_with_defaults();
        let ex = BatchExecutor::new(1 << 12);
        let batch = vec![
            Addressed::new("ghost", "table", Request::Sql("SELECT COUNT WHERE x = 1".into())),
            Addressed::new("ghost", "corpus", Request::Search(b"x".to_vec())),
            Addressed::new("ghost", "array", Request::Array(ArrayJob::Sum)),
        ];
        let (responses, _) = ex.execute(&mut pool, &refs(&batch));
        for r in &responses {
            assert!(matches!(r, Err(CpmError::Pool(_))), "{r:?}");
        }
        // A resident device of the wrong kind reports *what it is*, not
        // "missing".
        let wrong = Addressed::new(
            DEFAULT_TENANT,
            DEFAULT_CORPUS,
            Request::Sql("SELECT COUNT WHERE price < 1".into()),
        );
        let (responses, _) = ex.execute(&mut pool, &refs(std::slice::from_ref(&wrong)));
        assert_eq!(
            responses[0].as_ref().unwrap_err().to_string(),
            "pool error: device default/corpus is a corpus, not a table"
        );
    }
}
