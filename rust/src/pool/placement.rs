//! Multi-plane placement: PE-capacity accounting across several PE
//! planes and the data-movement cost model for crossing them.
//!
//! The paper budgets one CPM array (§8); MASIM-style deployments tile
//! *many* arrays behind one coordinator, so the pool splits its PE
//! budget into `planes` equal PE planes. A resident device lives
//! entirely on one plane (its home); executing a resident group on a
//! different plane first streams the device's content across the
//! exclusive bus, which the [`MoveCost`] model prices in device cycles.
//! The registry is pure policy — the allocator owns the per-entry plane
//! assignments and derives per-plane usage from them, so accounting can
//! never drift out of sync with the resident list.

/// Device-cycle price of moving a resident device between planes: one
/// fixed setup charge (bus arbitration, §3.2's exclusive-access setup)
/// plus a per-PE streaming charge over the exclusive bus (§4: content
/// moves one word per exclusive operation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoveCost {
    /// Fixed cycles to set up a cross-plane transfer.
    pub setup_cycles: u64,
    /// Cycles per PE streamed across planes.
    pub cycles_per_pe: u64,
}

impl Default for MoveCost {
    fn default() -> Self {
        MoveCost {
            setup_cycles: 64,
            cycles_per_pe: 1,
        }
    }
}

impl MoveCost {
    /// Cycles to move a `pes`-PE resident between planes.
    pub fn transfer_cycles(&self, pes: usize) -> u64 {
        self.setup_cycles + self.cycles_per_pe * pes as u64
    }
}

/// The plane layout of a pool: how many planes its PE budget is split
/// into, the per-plane capacity, and the cross-plane move price.
///
/// Placement is worst-fit (the plane with the most free PEs wins, ties
/// to the lowest plane id) so resident devices spread across planes and
/// the multi-plane scheduler has independent work per plane to overlap.
/// One plane (the default) makes every decision degenerate to the
/// single-plane pool the earlier tiers were built on.
#[derive(Debug, Clone)]
pub struct PlaneRegistry {
    planes: usize,
    cap_per_plane: usize,
    move_cost: MoveCost,
}

impl PlaneRegistry {
    /// Split `capacity_pes` into `planes` equal planes (at least one;
    /// a remainder that does not divide evenly is left unused).
    pub fn new(capacity_pes: usize, planes: usize) -> Self {
        let planes = planes.max(1);
        PlaneRegistry {
            planes,
            cap_per_plane: capacity_pes / planes,
            move_cost: MoveCost::default(),
        }
    }

    /// Number of PE planes.
    pub fn plane_count(&self) -> usize {
        self.planes
    }

    /// PE capacity of each plane.
    pub fn capacity_per_plane(&self) -> usize {
        self.cap_per_plane
    }

    /// The cross-plane data-movement cost model.
    pub fn move_cost(&self) -> MoveCost {
        self.move_cost
    }

    /// Cycles to move a `pes`-PE resident between planes.
    pub fn transfer_cycles(&self, pes: usize) -> u64 {
        self.move_cost.transfer_cycles(pes)
    }

    /// Worst-fit placement: the plane with the most free PEs that still
    /// fits `pes` (ties to the lowest plane id), or `None` when no plane
    /// fits. `used` is the current per-plane usage (one slot per plane).
    pub fn place(&self, used: &[usize], pes: usize) -> Option<usize> {
        debug_assert_eq!(used.len(), self.planes);
        used.iter()
            .enumerate()
            .filter(|&(_, &u)| u + pes <= self.cap_per_plane)
            .max_by(|a, b| {
                // Most free PEs wins; on a tie the *lower* id wins, so
                // reverse the id ordering inside the max.
                let free = |&(_, &u): &(usize, &usize)| self.cap_per_plane - u;
                free(a).cmp(&free(b)).then(b.0.cmp(&a.0))
            })
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_plane_owns_the_whole_budget() {
        let r = PlaneRegistry::new(1024, 1);
        assert_eq!(r.plane_count(), 1);
        assert_eq!(r.capacity_per_plane(), 1024);
        assert_eq!(r.place(&[0], 1024), Some(0));
        assert_eq!(r.place(&[1], 1024), None);
    }

    #[test]
    fn worst_fit_balances_and_ties_to_lowest_id() {
        let r = PlaneRegistry::new(1000, 2);
        assert_eq!(r.capacity_per_plane(), 500);
        // Empty planes tie: lowest id wins.
        assert_eq!(r.place(&[0, 0], 100), Some(0));
        // Plane 1 has more free room once plane 0 is loaded.
        assert_eq!(r.place(&[100, 0], 100), Some(1));
        // A device that only fits the emptier plane goes there.
        assert_eq!(r.place(&[450, 100], 200), Some(1));
        // Nothing fits anywhere.
        assert_eq!(r.place(&[450, 450], 100), None);
    }

    #[test]
    fn zero_planes_clamps_to_one() {
        let r = PlaneRegistry::new(512, 0);
        assert_eq!(r.plane_count(), 1);
        assert_eq!(r.capacity_per_plane(), 512);
    }

    #[test]
    fn move_cost_prices_setup_plus_streaming() {
        let r = PlaneRegistry::new(1 << 20, 4);
        let mc = r.move_cost();
        assert_eq!(r.transfer_cycles(0), mc.setup_cycles);
        assert_eq!(
            r.transfer_cycles(1000),
            mc.setup_cycles + 1000 * mc.cycles_per_pe
        );
    }
}
