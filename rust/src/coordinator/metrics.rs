//! Service metrics: request counters, per-tenant accounting, batching
//! gains, and latency percentiles.

use std::collections::BTreeMap;
use std::time::Duration;

/// Latency aggregation (wall-clock per request).
///
/// Percentile queries are served from a cached sorted snapshot of the
/// samples: recording stays an O(1) push, and the snapshot is re-sorted
/// at most once per batch of new recordings instead of on every
/// percentile read. The cache is a plain field (no interior
/// mutability), so the type stays `Sync`; percentile reads therefore
/// take `&mut self`.
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
    /// Sorted snapshot of `samples_us`; valid iff it has the same length
    /// (recording only ever appends).
    sorted: Vec<u64>,
}

impl LatencyStats {
    /// Record one sample.
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    /// Sample count.
    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Percentile in microseconds (p in 0..=100, nearest-rank over the
    /// sorted samples). Re-sorts the cached snapshot only if new samples
    /// arrived since the last call.
    pub fn percentile_us(&mut self, p: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        if self.sorted.len() != self.samples_us.len() {
            self.sorted.clear();
            self.sorted.extend_from_slice(&self.samples_us);
            self.sorted.sort_unstable();
        }
        let idx = ((p / 100.0) * (self.sorted.len() - 1) as f64).round() as usize;
        self.sorted[idx.min(self.sorted.len() - 1)]
    }

    /// Mean in microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }
}

/// Wire-level counters from the TCP front-end (`net/`): connection and
/// admission-window accounting on top of the in-process serving metrics.
#[derive(Debug, Default, Clone)]
pub struct WireMetrics {
    /// TCP connections accepted.
    pub connections: u64,
    /// Admission windows dispatched to the batch executor.
    pub windows: u64,
    /// Windows that coalesced more than one request into a single
    /// `handle_batch` call.
    pub coalesced_windows: u64,
    /// Largest window occupancy observed (requests in one window).
    pub max_window: u64,
    /// Requests admitted through the window (across all windows).
    pub window_requests: u64,
}

impl WireMetrics {
    /// Mean window occupancy (requests per dispatched window).
    pub fn mean_occupancy(&self) -> f64 {
        if self.windows == 0 {
            return 0.0;
        }
        self.window_requests as f64 / self.windows as f64
    }
}

/// Per-tenant service counters (quota attribution and billing view).
#[derive(Debug, Default, Clone)]
pub struct TenantMetrics {
    /// Requests attributed to this tenant.
    pub requests: u64,
    /// Failed requests.
    pub errors: u64,
    /// Concurrent macro cycles spent on this tenant's devices.
    pub macro_cycles: u64,
    /// Exclusive (addressed) ops spent on this tenant's devices.
    pub exclusive_ops: u64,
}

/// Server metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Requests served.
    pub requests: u64,
    /// Requests that failed.
    pub errors: u64,
    /// Concurrent macro cycles spent on devices.
    pub device_macro_cycles: u64,
    /// Exclusive ops spent on devices.
    pub device_exclusive_ops: u64,
    /// Batches admitted through the batch executor.
    pub batches: u64,
    /// Requests that arrived inside an explicit batch.
    pub batched_requests: u64,
    /// Device passes avoided by sharing compare/search passes in batches.
    pub shared_passes_saved: u64,
    /// Groups executed across all batches (a batch of n compatible
    /// requests can collapse to one group).
    pub groups_executed: u64,
    /// Makespan had each grouped (load, exec) phase run back-to-back.
    pub makespan_serial_cycles: u64,
    /// Makespan with exclusive-bus loads overlapped under concurrent
    /// execution (§3.1's two-phase pipeline).
    pub makespan_overlapped_cycles: u64,
    /// Per-tenant counters keyed by tenant name.
    pub per_tenant: BTreeMap<String, TenantMetrics>,
    /// Request latency.
    pub latency: LatencyStats,
    /// Wire-level counters (populated by the TCP front-end in `net/`).
    pub wire: WireMetrics,
}

impl Metrics {
    /// Mutable per-tenant counters (created on first use).
    pub fn tenant(&mut self, name: &str) -> &mut TenantMetrics {
        self.per_tenant.entry(name.to_string()).or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut l = LatencyStats::default();
        for us in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            l.record(Duration::from_micros(us));
        }
        assert_eq!(l.count(), 10);
        assert!(l.percentile_us(50.0) <= l.percentile_us(99.0));
        assert_eq!(l.percentile_us(0.0), 10);
        assert_eq!(l.percentile_us(100.0), 100);
        assert!((l.mean_us() - 55.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_are_zero() {
        let mut l = LatencyStats::default();
        assert_eq!(l.percentile_us(99.0), 0);
        assert_eq!(l.mean_us(), 0.0);
    }

    #[test]
    fn percentile_semantics_are_nearest_rank() {
        // Pin the exact interpolation: idx = round(p/100 * (len-1)).
        let mut l = LatencyStats::default();
        for us in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            l.record(Duration::from_micros(us));
        }
        assert_eq!(l.percentile_us(25.0), 30); // round(2.25) = 2
        assert_eq!(l.percentile_us(50.0), 60); // round(4.5)  = 5
        assert_eq!(l.percentile_us(75.0), 80); // round(6.75) = 7
        assert_eq!(l.percentile_us(90.0), 90); // round(8.1)  = 8
        assert_eq!(l.percentile_us(99.0), 100); // round(8.91) = 9
    }

    #[test]
    fn cached_sort_refreshes_after_new_samples() {
        // Out-of-order recording must still read off the sorted order,
        // and recording after a percentile call must invalidate the cache.
        let mut l = LatencyStats::default();
        for us in [50u64, 10, 40] {
            l.record(Duration::from_micros(us));
        }
        assert_eq!(l.percentile_us(0.0), 10);
        assert_eq!(l.percentile_us(50.0), 40);
        assert_eq!(l.percentile_us(100.0), 50);
        l.record(Duration::from_micros(5));
        assert_eq!(l.percentile_us(0.0), 5);
        assert_eq!(l.percentile_us(100.0), 50);
    }

    #[test]
    fn wire_occupancy_is_requests_per_window() {
        let mut w = WireMetrics::default();
        assert_eq!(w.mean_occupancy(), 0.0);
        w.windows = 4;
        w.window_requests = 10;
        w.coalesced_windows = 2;
        w.max_window = 5;
        assert!((w.mean_occupancy() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn tenant_counters_accumulate() {
        let mut m = Metrics::default();
        m.tenant("acme").requests += 3;
        m.tenant("acme").errors += 1;
        m.tenant("umbrella").requests += 2;
        assert_eq!(m.per_tenant["acme"].requests, 3);
        assert_eq!(m.per_tenant["acme"].errors, 1);
        assert_eq!(m.per_tenant["umbrella"].requests, 2);
        assert_eq!(m.per_tenant.len(), 2);
    }
}
