//! Service metrics — the types now live in the observability layer
//! ([`crate::obs`]) so the recorder, the Prometheus exporter, and the
//! wire codec share one definition. This module re-exports them under
//! the original `coordinator::metrics` paths.
//!
//! The old in-place mutable `Metrics` (unbounded latency sample vector,
//! `&mut self` percentile reads) is gone: [`CpmServer`] records into a
//! shared [`Recorder`](crate::obs::Recorder) and
//! [`CpmServer::metrics`] returns an owned snapshot whose reads all
//! take `&self`.
//!
//! [`CpmServer`]: super::CpmServer
//! [`CpmServer::metrics`]: super::CpmServer::metrics

pub use crate::obs::{
    GaugeStats, LatencyStats, Metrics, Percentiles, SpanStats, TenantMetrics, WireMetrics,
};
