//! Service metrics: request counters and latency percentiles.

use std::time::Duration;

/// Latency aggregation (wall-clock per request).
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    /// Record one sample.
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    /// Sample count.
    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Percentile in microseconds (p in 0..=100).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut s = self.samples_us.clone();
        s.sort_unstable();
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    /// Mean in microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }
}

/// Server metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Requests served.
    pub requests: u64,
    /// Requests that failed.
    pub errors: u64,
    /// Concurrent macro cycles spent on devices.
    pub device_macro_cycles: u64,
    /// Exclusive ops spent on devices.
    pub device_exclusive_ops: u64,
    /// Request latency.
    pub latency: LatencyStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut l = LatencyStats::default();
        for us in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            l.record(Duration::from_micros(us));
        }
        assert_eq!(l.count(), 10);
        assert!(l.percentile_us(50.0) <= l.percentile_us(99.0));
        assert_eq!(l.percentile_us(0.0), 10);
        assert_eq!(l.percentile_us(100.0), 100);
        assert!((l.mean_us() - 55.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_are_zero() {
        let l = LatencyStats::default();
        assert_eq!(l.percentile_us(99.0), 0);
        assert_eq!(l.mean_us(), 0.0);
    }
}
