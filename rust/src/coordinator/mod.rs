//! Coordinator — the smart-memory server (§2's shared "ultra-fast SQL
//! engine", §8's multi-task discussion).
//!
//! The CPM devices are passive bus devices; this layer makes them a
//! service: a request router in front of a device pool, a batcher that
//! groups compatible requests, and a scheduler that overlaps exclusive-bus
//! loads with concurrent execution (§3.1: "while some addressable
//! registers of one task are operated on concurrently, other addressable
//! registers in the same CPM can be prepared for other tasks by exclusive
//! operations").

pub mod metrics;
pub mod scheduler;
pub mod server;

pub use metrics::{LatencyStats, Metrics};
pub use scheduler::{OverlapScheduler, TaskPhase};
pub use server::{CpmServer, Request, Response};
