//! Coordinator — the smart-memory server (§2's shared "ultra-fast SQL
//! engine", §8's multi-task discussion).
//!
//! The CPM devices are passive bus devices; this layer makes them a
//! service: a request router in front of the multi-tenant
//! [`DevicePool`](crate::pool::DevicePool), a batch path that groups
//! compatible requests into shared device passes, and the §3.1/§8
//! scheduler that overlaps exclusive-bus loads with concurrent execution
//! ("while some addressable registers of one task are operated on
//! concurrently, other addressable registers in the same CPM can be
//! prepared for other tasks by exclusive operations").

pub mod scheduler;
pub mod server;

pub use scheduler::{OverlapScheduler, PlacedTask, TaskPhase};
pub use server::{
    Addressed, ArrayJob, CpmServer, Request, Response, DEFAULT_ARRAY, DEFAULT_CORPUS,
    DEFAULT_TABLE, DEFAULT_TENANT,
};
