//! The smart-memory server: request routing over a multi-tenant device
//! pool.
//!
//! Clients submit [`Request`]s — bare (routed to the default tenant's
//! default devices, the original single-resident view) or wrapped in an
//! [`Addressed`] envelope naming a tenant and a device. Every path,
//! including single requests, goes through the
//! [`BatchExecutor`](crate::pool::BatchExecutor) as a batch of one, so
//! serving always uses the same grouping, cost attribution, and overlap
//! accounting (§2's networked SQL engine; §3.1's exclusive/concurrent
//! overlap; E17/E20 end-to-end drivers). All four CPM family members are
//! reachable through [`CpmServer::handle`].

use std::sync::Arc;
use std::time::Instant;

use crate::error::Result;
use crate::obs::{Metrics, Recorder};
use crate::pool::{AddressedRef, BatchExecutor, DevicePool, PoolConfig};
use crate::sql::{QueryResult, Schema, Table};

/// Tenant used when a request carries no explicit tenant.
pub const DEFAULT_TENANT: &str = "default";
/// Default resident SQL-table name.
pub const DEFAULT_TABLE: &str = "table";
/// Default resident corpus name.
pub const DEFAULT_CORPUS: &str = "corpus";
/// Default resident scratch-array name.
pub const DEFAULT_ARRAY: &str = "array";

/// A client request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// SQL query against a resident table.
    Sql(String),
    /// Substring search in a resident corpus.
    Search(Vec<u8>),
    /// Insert bytes into a resident corpus at a byte offset (content
    /// movable memory, §4: ~len concurrent cycles, no memmove).
    Insert(usize, Vec<u8>),
    /// Delete a byte range `(offset, len)` from a resident corpus.
    Delete(usize, usize),
    /// Replace every occurrence of a pattern in a corpus (§5.3's
    /// combined search + move device).
    Replace(Vec<u8>, Vec<u8>),
    /// Sum of an ad-hoc array.
    Sum(Vec<i32>),
    /// Maximum of an ad-hoc array.
    Max(Vec<i32>),
    /// Sort an ad-hoc array.
    Sort(Vec<i32>),
    /// Count values above a threshold.
    Threshold(Vec<i32>, i32),
    /// Histogram with the given bounds.
    Histogram(Vec<i32>, Vec<i32>),
    /// Run a job against a resident scratch array (addressed by device
    /// name; the load phase was paid at admission).
    Array(ArrayJob),
}

/// A job against a resident computable-memory scratch array. Jobs are
/// read-only queries: `Sort` returns the sorted copy without disturbing
/// the resident content.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArrayJob {
    /// Sum of the resident array.
    Sum,
    /// Maximum of the resident array.
    Max,
    /// Sorted copy of the resident array.
    Sort,
    /// Count of resident values above a threshold.
    Threshold(i32),
    /// Histogram of the resident array with the given bucket bounds.
    Histogram(Vec<i32>),
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Row set or count from SQL.
    Sql(QueryResult),
    /// Match end positions.
    Matches(Vec<usize>),
    /// Scalar result.
    Scalar(i64),
    /// Sorted array.
    Sorted(Vec<i32>),
    /// Histogram counts.
    Histogram(Vec<usize>),
    /// Live metrics snapshot (reply to a wire `Stats` scrape; boxed —
    /// the snapshot is much larger than the other variants).
    Stats(Box<Metrics>),
}

/// A request addressed to a tenant's named device — the multi-tenant
/// envelope. [`Addressed::local`] (or `Request::into`) selects the
/// default tenant and per-kind default device names, which is exactly the
/// single-resident server the pre-pool API exposed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Addressed {
    /// Owning tenant (quota and metrics attribution).
    pub tenant: String,
    /// Target device name; `None` selects the default for the op kind.
    pub device: Option<String>,
    /// The operation.
    pub op: Request,
}

impl Addressed {
    /// Address `op` to `tenant`'s device `device`.
    pub fn new(tenant: &str, device: &str, op: Request) -> Self {
        Addressed {
            tenant: tenant.to_string(),
            device: Some(device.to_string()),
            op,
        }
    }

    /// Address `op` to `tenant`'s default device for the op kind.
    pub fn for_tenant(tenant: &str, op: Request) -> Self {
        Addressed {
            tenant: tenant.to_string(),
            device: None,
            op,
        }
    }

    /// Address `op` to the default tenant's default devices.
    pub fn local(op: Request) -> Self {
        Addressed::for_tenant(DEFAULT_TENANT, op)
    }

    /// The resident device this request targets: the explicit name, or
    /// the default for the op kind ([`DEFAULT_TABLE`] for SQL,
    /// [`DEFAULT_CORPUS`] for search/edit, [`DEFAULT_ARRAY`] for array
    /// jobs). Ad-hoc compute ops target no resident device.
    pub fn device_name(&self) -> &str {
        match &self.device {
            Some(d) => d,
            None => default_device(&self.op),
        }
    }
}

/// Default device name for an op kind (empty for ad-hoc compute, which
/// targets no resident device).
pub(crate) fn default_device(op: &Request) -> &'static str {
    match op {
        Request::Sql(_) => DEFAULT_TABLE,
        Request::Search(_)
        | Request::Insert(..)
        | Request::Delete(..)
        | Request::Replace(..) => DEFAULT_CORPUS,
        Request::Array(_) => DEFAULT_ARRAY,
        _ => "",
    }
}

impl From<Request> for Addressed {
    fn from(op: Request) -> Self {
        Addressed::local(op)
    }
}

/// The server: a device pool, a batch executor, and a shared metrics
/// recorder. Every serving path records into the recorder (`&self`
/// atomics), and [`CpmServer::metrics`] reads an owned snapshot — other
/// threads holding the [`Recorder`] through [`CpmServer::recorder`]
/// (the TCP front-end, scrape answerers) observe the same ledger
/// without touching the server.
#[derive(Debug)]
pub struct CpmServer {
    pool: DevicePool,
    executor: BatchExecutor,
    obs: Arc<Recorder>,
}

impl CpmServer {
    /// Build a single-tenant server: one table (schema + capacity), one
    /// text corpus, and a computable-memory capacity for ad-hoc array
    /// jobs — the original API, now backed by a pool sized to fit both
    /// pinned default devices. The corpus keeps the pool's slack policy
    /// (`PoolConfig::corpus_slack`) of spare PEs for copy-free insertions.
    pub fn new(schema: Schema, max_rows: usize, corpus: &[u8], engine_capacity: usize) -> Self {
        let defaults = PoolConfig::default();
        let table_pes = (schema.row_size() * max_rows).max(1);
        let corpus_pes = (corpus.len() + defaults.corpus_slack).max(1);
        let mut pool = DevicePool::new(PoolConfig {
            capacity_pes: table_pes + corpus_pes,
            tenant_quota_pes: table_pes + corpus_pes,
            ..defaults
        });
        pool.create_table(DEFAULT_TENANT, DEFAULT_TABLE, schema, max_rows)
            .expect("default table fits its own pool");
        pool.create_corpus(DEFAULT_TENANT, DEFAULT_CORPUS, corpus)
            .expect("default corpus fits its own pool");
        pool.pin(DEFAULT_TENANT, DEFAULT_TABLE, true)
            .expect("default table resident");
        pool.pin(DEFAULT_TENANT, DEFAULT_CORPUS, true)
            .expect("default corpus resident");
        Self::with_pool(pool, engine_capacity)
    }

    /// Build a server over an externally configured pool (multi-tenant
    /// setups: several tables/corpora/arrays, quotas, custom slack). The
    /// pool's [`PoolConfig::exec`] policy flows into the batch executor,
    /// so compute on large planes runs sharded across threads.
    pub fn with_pool(pool: DevicePool, engine_capacity: usize) -> Self {
        let exec = pool.config().exec;
        let obs = Arc::new(Recorder::new());
        obs.set_planes(pool.plane_count() as u64);
        obs.sample_planes(&pool.plane_used_pes());
        CpmServer {
            pool,
            executor: BatchExecutor::with_exec(engine_capacity, exec),
            obs,
        }
    }

    /// Snapshot of every service metric (counters, per-tenant ledger,
    /// latency histogram, wire counters, spans, gauges). Owned plain
    /// data: all reads on it take `&self`.
    pub fn metrics(&self) -> Metrics {
        self.obs.snapshot()
    }

    /// The shared metrics recorder. The TCP front-end clones this to
    /// record wire counters and spans, and to answer `Stats` scrapes
    /// from reader threads without involving the dispatcher.
    pub fn recorder(&self) -> Arc<Recorder> {
        Arc::clone(&self.obs)
    }

    /// The plane-execution policy in force (carries the worker-pool
    /// handle gauges are sampled from).
    pub fn exec(&self) -> &crate::device::computable::ExecConfig {
        self.executor.exec()
    }

    /// Change the plane-execution policy after construction (the CLI
    /// `--threads` / `--backend` flags and the `CPM_THREADS` /
    /// `CPM_BACKEND` environment land here for servers built with
    /// [`CpmServer::new`]).
    pub fn set_exec(&mut self, exec: crate::device::computable::ExecConfig) {
        self.executor.set_exec(exec);
    }

    /// The device pool (inspection: residents, stats, quotas).
    pub fn pool(&self) -> &DevicePool {
        &self.pool
    }

    /// Mutable pool access (admissions, pinning, quota changes).
    pub fn pool_mut(&mut self) -> &mut DevicePool {
        &mut self.pool
    }

    /// Load rows into the default tenant's default table.
    pub fn load_rows(&mut self, rows: &[Vec<u64>]) -> Result<()> {
        self.load_rows_into(DEFAULT_TENANT, DEFAULT_TABLE, rows)
    }

    /// Load rows into a named resident table.
    pub fn load_rows_into(&mut self, tenant: &str, name: &str, rows: &[Vec<u64>]) -> Result<()> {
        let table = self.pool.table_mut(tenant, name)?;
        for r in rows {
            table.insert(r)?;
        }
        Ok(())
    }

    /// Access the default resident table.
    ///
    /// # Panics
    ///
    /// Panics if `default/table` is not resident: servers built with
    /// [`CpmServer::with_pool`] must create (and should pin) a default
    /// table before using this accessor — [`CpmServer::new`] does both.
    /// Pool-first callers should prefer `server.pool().table(...)`.
    pub fn table(&self) -> &Table {
        self.pool
            .table(DEFAULT_TENANT, DEFAULT_TABLE)
            .expect("no resident default/table (create and pin one, or use pool().table())")
    }

    /// Handle one request against the default tenant's devices — the
    /// original request-routing entry point. The payload is borrowed,
    /// not cloned.
    pub fn handle(&mut self, req: &Request) -> Result<Response> {
        let r = AddressedRef {
            tenant: DEFAULT_TENANT,
            device: None,
            op: req,
        };
        self.run_refs(std::slice::from_ref(&r))
            .pop()
            .expect("one response per request")
    }

    /// Alias for [`CpmServer::handle`] (the original name; kept for
    /// existing callers).
    pub fn serve(&mut self, req: &Request) -> Result<Response> {
        self.handle(req)
    }

    /// Handle one tenant/device-addressed request.
    pub fn handle_addressed(&mut self, req: &Addressed) -> Result<Response> {
        self.run_refs(std::slice::from_ref(&AddressedRef::from(req)))
            .pop()
            .expect("one response per request")
    }

    /// Handle a queue of requests as one batch: compatible work is
    /// grouped into shared device passes and the resulting (load, exec)
    /// phases are overlap-scheduled. Responses align with `batch` order
    /// and are identical to serving the queue one request at a time.
    pub fn handle_batch(&mut self, batch: &[Addressed]) -> Vec<Result<Response>> {
        self.obs.batch_admitted(batch.len() as u64);
        let refs: Vec<AddressedRef<'_>> = batch.iter().map(AddressedRef::from).collect();
        self.run_refs(&refs)
    }

    fn run_refs(&mut self, batch: &[AddressedRef<'_>]) -> Vec<Result<Response>> {
        let start = Instant::now();
        let (responses, report) = self.executor.execute(&mut self.pool, batch);
        let elapsed = start.elapsed();
        self.obs.requests_served(batch.len() as u64);
        for (a, r) in batch.iter().zip(&responses) {
            let failed = r.is_err();
            if failed {
                self.obs.request_error();
            }
            self.obs.tenant(a.tenant, |t| {
                t.requests += 1;
                if failed {
                    t.errors += 1;
                }
            });
        }
        for (tenant, cost) in &report.group_costs {
            self.obs.device_cost(cost.macro_cycles, cost.exclusive_ops);
            self.obs.tenant(tenant, |t| {
                t.macro_cycles += cost.macro_cycles;
                t.exclusive_ops += cost.exclusive_ops;
            });
        }
        self.obs.batch_totals(
            report.shared_passes,
            report.groups,
            report.makespan_serial,
            report.makespan_overlapped,
            report.plan_ns,
        );
        // Multi-plane accounting: the placed makespan and what the §8 DMA
        // side bus shaved off it, plus fresh per-plane occupancy.
        self.obs.record_multi(
            report.makespan_multi,
            report.makespan_multi.saturating_sub(report.makespan_dma),
        );
        self.obs.sample_planes(&self.pool.plane_used_pes());
        // Per-request latency: the batch's wall time amortized over its
        // requests (they all complete when the batch completes).
        let per_request = elapsed / batch.len().max(1) as u32;
        self.obs.record_latency_n(per_request, batch.len() as u64);
        responses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::CpmError;
    use crate::pool::PoolConfig;
    use crate::sql::Query;
    use crate::util::rng::Rng;

    fn server() -> CpmServer {
        let schema = Schema::new(&[("price", 2), ("qty", 1)]).unwrap();
        let corpus = b"the quick brown fox jumps over the lazy dog";
        let mut s = CpmServer::new(schema, 256, corpus, 1 << 16);
        let mut rng = Rng::new(201);
        let rows: Vec<Vec<u64>> = (0..200)
            .map(|_| vec![rng.below(10_000), rng.below(100)])
            .collect();
        s.load_rows(&rows).unwrap();
        s
    }

    #[test]
    fn serves_sql() {
        let mut s = server();
        let r = s
            .serve(&Request::Sql("SELECT COUNT WHERE price < 5000".into()))
            .unwrap();
        let want = s
            .table()
            .query_reference(&Query::parse("SELECT COUNT WHERE price < 5000").unwrap());
        assert_eq!(r, Response::Sql(want));
        let m = s.metrics();
        assert_eq!(m.requests, 1);
        assert!(m.device_macro_cycles > 0);
    }

    #[test]
    fn serves_search() {
        let mut s = server();
        let r = s.serve(&Request::Search(b"the".to_vec())).unwrap();
        assert_eq!(r, Response::Matches(vec![2, 33]));
    }

    #[test]
    fn serves_array_jobs() {
        let mut s = server();
        let mut rng = Rng::new(202);
        let vals = rng.vec_i32(500, -100, 100);
        let want_sum: i64 = vals.iter().map(|&v| v as i64).sum();
        assert_eq!(
            s.serve(&Request::Sum(vals.clone())).unwrap(),
            Response::Scalar(want_sum)
        );
        let want_max = *vals.iter().max().unwrap() as i64;
        assert_eq!(
            s.serve(&Request::Max(vals.clone())).unwrap(),
            Response::Scalar(want_max)
        );
        let mut sorted = vals.clone();
        sorted.sort_unstable();
        assert_eq!(
            s.serve(&Request::Sort(vals.clone())).unwrap(),
            Response::Sorted(sorted)
        );
        let above = vals.iter().filter(|&&v| v > 0).count() as i64;
        assert_eq!(
            s.serve(&Request::Threshold(vals.clone(), 0)).unwrap(),
            Response::Scalar(above)
        );
        if let Response::Histogram(h) = s
            .serve(&Request::Histogram(vals.clone(), vec![-50, 0, 50]))
            .unwrap()
        {
            assert_eq!(h.iter().sum::<usize>(), vals.len());
        } else {
            panic!("expected histogram");
        }
        let m = s.metrics();
        assert_eq!(m.requests, 5);
        assert_eq!(m.errors, 0);
    }

    #[test]
    fn rejects_oversized_and_bad_requests() {
        let mut s = server();
        assert!(s.serve(&Request::Max(Vec::new())).is_err());
        assert!(s.serve(&Request::Sql("garbage".into())).is_err());
        assert_eq!(s.metrics().errors, 2);
        let schema = Schema::new(&[("x", 1)]).unwrap();
        let mut tiny = CpmServer::new(schema, 4, b"", 8);
        assert!(tiny.serve(&Request::Sum(vec![1; 100])).is_err());
    }

    #[test]
    fn insert_beyond_corpus_capacity_is_typed_and_harmless() {
        // Slack policy through the pool allocator: a 4-byte slack corpus
        // rejects a 10-byte insert with a typed capacity error and leaves
        // the content untouched (regression for the old panic-prone
        // fixed-slack growth path).
        let mut pool = DevicePool::new(PoolConfig {
            capacity_pes: 1 << 10,
            tenant_quota_pes: 1 << 10,
            corpus_slack: 4,
            ..PoolConfig::default()
        });
        pool.create_corpus(DEFAULT_TENANT, DEFAULT_CORPUS, b"abcdef")
            .unwrap();
        let mut s = CpmServer::with_pool(pool, 16);
        let err = s
            .serve(&Request::Insert(0, b"0123456789".to_vec()))
            .unwrap_err();
        assert!(
            matches!(
                err,
                CpmError::CapacityExceeded {
                    needed: 16,
                    available: 10,
                    ..
                }
            ),
            "{err}"
        );
        assert_eq!(
            s.pool().corpus(DEFAULT_TENANT, DEFAULT_CORPUS).unwrap().content(),
            b"abcdef"
        );
        // A fitting insert still works.
        assert_eq!(
            s.serve(&Request::Insert(6, b"ghij".to_vec())).unwrap(),
            Response::Scalar(10)
        );
    }

    #[test]
    fn per_tenant_metrics_and_addressing() {
        let mut pool = DevicePool::new(PoolConfig {
            capacity_pes: 1 << 14,
            tenant_quota_pes: 1 << 13,
            corpus_slack: 16,
            ..PoolConfig::default()
        });
        pool.create_corpus("alice", "notes", b"alpha beta alpha").unwrap();
        pool.create_corpus("bob", "notes", b"gamma delta").unwrap();
        let mut s = CpmServer::with_pool(pool, 1 << 10);
        let r = s
            .handle_addressed(&Addressed::new("alice", "notes", Request::Search(b"alpha".to_vec())))
            .unwrap();
        assert_eq!(r, Response::Matches(vec![4, 15]));
        let r = s
            .handle_addressed(&Addressed::new("bob", "notes", Request::Search(b"alpha".to_vec())))
            .unwrap();
        assert_eq!(r, Response::Matches(Vec::new()));
        // Wrong tenant/device addressing fails typed.
        assert!(s
            .handle_addressed(&Addressed::new("carol", "notes", Request::Search(b"x".to_vec())))
            .is_err());
        let m = s.metrics();
        assert_eq!(m.per_tenant["alice"].requests, 1);
        assert_eq!(m.per_tenant["bob"].requests, 1);
        assert_eq!(m.per_tenant["carol"].errors, 1);
        assert!(m.per_tenant["alice"].macro_cycles > 0);
    }

    #[test]
    fn batch_matches_serial_and_records_makespans() {
        let mut batched = server();
        let mut serial = server();
        let batch: Vec<Addressed> = vec![
            Addressed::local(Request::Sql("SELECT COUNT WHERE price < 5000".into())),
            Addressed::local(Request::Search(b"the".to_vec())),
            Addressed::local(Request::Sql("SELECT COUNT WHERE price < 5000".into())),
            Addressed::local(Request::Insert(0, b"zz".to_vec())),
            Addressed::local(Request::Search(b"the".to_vec())),
            Addressed::local(Request::Sum(vec![5, 6, 7])),
        ];
        let got = batched.handle_batch(&batch);
        for (g, a) in got.iter().zip(&batch) {
            let want = serial.handle_addressed(a);
            match (g, &want) {
                (Ok(x), Ok(y)) => assert_eq!(x, y),
                (Err(_), Err(_)) => {}
                other => panic!("batched/serial divergence: {other:?}"),
            }
        }
        let m = batched.metrics();
        assert_eq!(m.batches, 1);
        assert_eq!(m.batched_requests, 6);
        assert!(m.shared_passes_saved >= 1);
        assert!(m.makespan_overlapped_cycles <= m.makespan_serial_cycles);
        assert!(m.makespan_multi_cycles <= m.makespan_overlapped_cycles);
        // Single-plane server, DMA off: nothing for the side bus to save.
        assert_eq!(m.dma_saved_cycles, 0);
        assert_eq!(m.gauges.planes, 1);
        assert_eq!(m.gauges.plane_used_pes.len(), 1);
        assert_eq!(m.latency.count(), 6);
    }
}
