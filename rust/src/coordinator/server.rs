//! The smart-memory server: request routing over a device pool.
//!
//! Clients submit [`Request`]s; the server routes SQL to the comparable-
//! memory table, substring searches and copy-free edits to the combined
//! searchable+movable corpus (§5.3), and array jobs
//! (sum/max/sort/threshold/histogram) to the computable memory — one
//! shared SIMD device pool serving many tasks (§2's networked SQL engine;
//! E17's end-to-end driver). All four CPM family members are reachable
//! through [`CpmServer::handle`].

use std::time::Instant;

use crate::algos::{histogram, reduce, sort, threshold};
use crate::cycles::ConcurrentCost;
use crate::device::computable::{Reg, WordEngine};
use crate::device::mutable_search::MutableSearchableMemory;
use crate::error::{CpmError, Result};
use crate::sql::{Query, QueryResult, Schema, Table};

use super::metrics::Metrics;

/// Spare PEs kept beyond the initial corpus so concurrent-move edits
/// (insertions) have room to shift into.
const CORPUS_SLACK: usize = 4096;

/// A client request.
#[derive(Debug, Clone)]
pub enum Request {
    /// SQL query against the resident table.
    Sql(String),
    /// Substring search in the resident corpus.
    Search(Vec<u8>),
    /// Insert bytes into the resident corpus at a byte offset (content
    /// movable memory, §4: ~len concurrent cycles, no memmove).
    Insert(usize, Vec<u8>),
    /// Delete a byte range `(offset, len)` from the resident corpus.
    Delete(usize, usize),
    /// Replace every occurrence of a pattern in the corpus (§5.3's
    /// combined search + move device).
    Replace(Vec<u8>, Vec<u8>),
    /// Sum of an ad-hoc array.
    Sum(Vec<i32>),
    /// Maximum of an ad-hoc array.
    Max(Vec<i32>),
    /// Sort an ad-hoc array.
    Sort(Vec<i32>),
    /// Count values above a threshold.
    Threshold(Vec<i32>, i32),
    /// Histogram with the given bounds.
    Histogram(Vec<i32>, Vec<i32>),
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Row set or count from SQL.
    Sql(QueryResult),
    /// Match end positions.
    Matches(Vec<usize>),
    /// Scalar result.
    Scalar(i64),
    /// Sorted array.
    Sorted(Vec<i32>),
    /// Histogram counts.
    Histogram(Vec<usize>),
}

/// The server: one table, one editable text corpus, one computable engine.
#[derive(Debug)]
pub struct CpmServer {
    table: Table,
    corpus: MutableSearchableMemory,
    engine_capacity: usize,
    /// Service metrics.
    pub metrics: Metrics,
}

impl CpmServer {
    /// Build a server with a table schema + capacity, a text corpus, and a
    /// computable-memory capacity for ad-hoc array jobs. The corpus device
    /// keeps [`CORPUS_SLACK`] spare PEs for copy-free insertions.
    pub fn new(schema: Schema, max_rows: usize, corpus: &[u8], engine_capacity: usize) -> Self {
        let mut mem = MutableSearchableMemory::new(corpus.len() + CORPUS_SLACK);
        mem.load(corpus).expect("corpus fits its own device");
        CpmServer {
            table: Table::new(schema, max_rows),
            corpus: mem,
            engine_capacity,
            metrics: Metrics::default(),
        }
    }

    /// Load rows into the table.
    pub fn load_rows(&mut self, rows: &[Vec<u64>]) -> Result<()> {
        for r in rows {
            self.table.insert(r)?;
        }
        Ok(())
    }

    /// Access the resident table.
    pub fn table(&self) -> &Table {
        &self.table
    }

    /// Handle one request — the request-routing entry point.
    pub fn handle(&mut self, req: &Request) -> Result<Response> {
        let start = Instant::now();
        let out = self.dispatch(req);
        self.metrics.requests += 1;
        if out.is_err() {
            self.metrics.errors += 1;
        }
        self.metrics.latency.record(start.elapsed());
        out
    }

    /// Alias for [`CpmServer::handle`] (the original name; kept for
    /// existing callers).
    pub fn serve(&mut self, req: &Request) -> Result<Response> {
        self.handle(req)
    }

    fn charge(&mut self, cost: ConcurrentCost) {
        self.metrics.device_macro_cycles += cost.macro_cycles;
        self.metrics.device_exclusive_ops += cost.exclusive_ops;
    }

    fn dispatch(&mut self, req: &Request) -> Result<Response> {
        match req {
            Request::Sql(text) => {
                let q = Query::parse(text)?;
                self.table.reset_device_cost();
                let r = self.table.query(&q)?;
                let cost = self.table.device_cost();
                self.charge(cost);
                Ok(Response::Sql(r))
            }
            Request::Search(pattern) => {
                self.corpus.reset_cost();
                let hits = self.corpus.find(pattern);
                let cost = self.corpus.cost();
                self.charge(cost);
                Ok(Response::Matches(hits))
            }
            Request::Insert(at, data) => {
                self.corpus.reset_cost();
                self.corpus.insert(*at, data)?;
                let cost = self.corpus.cost();
                self.charge(cost);
                Ok(Response::Scalar(self.corpus.len() as i64))
            }
            Request::Delete(at, len) => {
                self.corpus.reset_cost();
                self.corpus.delete(*at, *len)?;
                let cost = self.corpus.cost();
                self.charge(cost);
                Ok(Response::Scalar(self.corpus.len() as i64))
            }
            Request::Replace(pattern, replacement) => {
                self.corpus.reset_cost();
                let n = self.corpus.replace_all(pattern, replacement)?;
                let cost = self.corpus.cost();
                self.charge(cost);
                Ok(Response::Scalar(n as i64))
            }
            Request::Sum(values) => {
                let mut e = self.engine_for(values)?;
                let run = reduce::sum_1d_opt(&mut e, values.len());
                self.charge(e.cost());
                Ok(Response::Scalar(run.value))
            }
            Request::Max(values) => {
                if values.is_empty() {
                    return Err(CpmError::Coordinator("max of empty array".into()));
                }
                let mut e = self.engine_for(values)?;
                let m = crate::util::isqrt(values.len() as u64).max(1) as usize;
                let run = reduce::max_1d(&mut e, values.len(), m);
                self.charge(e.cost());
                Ok(Response::Scalar(run.value as i64))
            }
            Request::Sort(values) => {
                let mut e = self.engine_for(values)?;
                sort::sort_sqrt(&mut e, values.len());
                self.charge(e.cost());
                Ok(Response::Sorted(e.plane(Reg::Nb)[..values.len()].to_vec()))
            }
            Request::Threshold(values, t) => {
                let mut e = self.engine_for(values)?;
                let count = threshold::threshold_mark(&mut e, values.len(), *t);
                self.charge(e.cost());
                Ok(Response::Scalar(count as i64))
            }
            Request::Histogram(values, bounds) => {
                let mut e = self.engine_for(values)?;
                let counts = histogram::histogram_words(&mut e, values.len(), bounds);
                self.charge(e.cost());
                Ok(Response::Histogram(counts))
            }
        }
    }

    fn engine_for(&mut self, values: &[i32]) -> Result<WordEngine> {
        if values.len() > self.engine_capacity {
            return Err(CpmError::Coordinator(format!(
                "array of {} exceeds device capacity {}",
                values.len(),
                self.engine_capacity
            )));
        }
        let mut e = WordEngine::new(values.len().max(1), 16);
        e.load_plane(Reg::Nb, values);
        Ok(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn server() -> CpmServer {
        let schema = Schema::new(&[("price", 2), ("qty", 1)]).unwrap();
        let corpus = b"the quick brown fox jumps over the lazy dog";
        let mut s = CpmServer::new(schema, 256, corpus, 1 << 16);
        let mut rng = Rng::new(201);
        let rows: Vec<Vec<u64>> = (0..200)
            .map(|_| vec![rng.below(10_000), rng.below(100)])
            .collect();
        s.load_rows(&rows).unwrap();
        s
    }

    #[test]
    fn serves_sql() {
        let mut s = server();
        let r = s
            .serve(&Request::Sql("SELECT COUNT WHERE price < 5000".into()))
            .unwrap();
        let want = s
            .table()
            .query_reference(&Query::parse("SELECT COUNT WHERE price < 5000").unwrap());
        assert_eq!(r, Response::Sql(want));
        assert_eq!(s.metrics.requests, 1);
        assert!(s.metrics.device_macro_cycles > 0);
    }

    #[test]
    fn serves_search() {
        let mut s = server();
        let r = s.serve(&Request::Search(b"the".to_vec())).unwrap();
        assert_eq!(r, Response::Matches(vec![2, 33]));
    }

    #[test]
    fn serves_array_jobs() {
        let mut s = server();
        let mut rng = Rng::new(202);
        let vals = rng.vec_i32(500, -100, 100);
        let want_sum: i64 = vals.iter().map(|&v| v as i64).sum();
        assert_eq!(
            s.serve(&Request::Sum(vals.clone())).unwrap(),
            Response::Scalar(want_sum)
        );
        let want_max = *vals.iter().max().unwrap() as i64;
        assert_eq!(
            s.serve(&Request::Max(vals.clone())).unwrap(),
            Response::Scalar(want_max)
        );
        let mut sorted = vals.clone();
        sorted.sort_unstable();
        assert_eq!(
            s.serve(&Request::Sort(vals.clone())).unwrap(),
            Response::Sorted(sorted)
        );
        let above = vals.iter().filter(|&&v| v > 0).count() as i64;
        assert_eq!(
            s.serve(&Request::Threshold(vals.clone(), 0)).unwrap(),
            Response::Scalar(above)
        );
        if let Response::Histogram(h) = s
            .serve(&Request::Histogram(vals.clone(), vec![-50, 0, 50]))
            .unwrap()
        {
            assert_eq!(h.iter().sum::<usize>(), vals.len());
        } else {
            panic!("expected histogram");
        }
        assert_eq!(s.metrics.requests, 5);
        assert_eq!(s.metrics.errors, 0);
        assert!(s.metrics.latency.percentile_us(99.0) > 0);
    }

    #[test]
    fn rejects_oversized_and_bad_requests() {
        let mut s = server();
        assert!(s.serve(&Request::Max(Vec::new())).is_err());
        assert!(s.serve(&Request::Sql("garbage".into())).is_err());
        assert_eq!(s.metrics.errors, 2);
        let schema = Schema::new(&[("x", 1)]).unwrap();
        let mut tiny = CpmServer::new(schema, 4, b"", 8);
        assert!(tiny.serve(&Request::Sum(vec![1; 100])).is_err());
    }
}
