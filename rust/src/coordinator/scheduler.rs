//! Overlap scheduler (§3.1, §8 — E18, E24).
//!
//! A CPM's concurrent bus and exclusive bus are independent: while one
//! task's registers are driven by broadcast instructions, another task's
//! data can stream in through addressed writes. This scheduler models a
//! two-phase task pipeline (load → execute) and computes the makespan
//! with and without overlap, plus the §8 DMA-bus variant where loads go
//! through a dedicated side bus.
//!
//! The multi-plane variants ([`OverlapScheduler::makespan_multi`],
//! [`OverlapScheduler::makespan_multi_with_dma`]) schedule
//! [`PlacedTask`]s across several PE planes: each plane runs its own
//! load/exec pipeline, executing a resident task away from its home
//! plane pays the cross-plane move cost, and the DMA side bus scales the
//! load phases. Both pick the best of a small deterministic candidate
//! set that always contains the home-partition schedule, so
//! `makespan_multi <= makespan_overlapped` and
//! `makespan_multi_with_dma <= makespan_multi` hold by construction —
//! the inequalities the E24 bench and the propcheck suite assert.

/// One task's device-cycle demands.
#[derive(Debug, Clone, Copy)]
pub struct TaskPhase {
    /// Exclusive-bus cycles to load the task's data.
    pub load_cycles: u64,
    /// Concurrent-bus cycles to execute it.
    pub exec_cycles: u64,
}

/// A task with a plane placement: its (load, exec) phases, the home
/// plane of the resident device it targets (`None` for ad-hoc compute,
/// which can run anywhere for free), and the device-cycle price of
/// executing it away from home.
#[derive(Debug, Clone, Copy)]
pub struct PlacedTask {
    /// Device-cycle demands of the task.
    pub phase: TaskPhase,
    /// Home plane of the target resident device (`None` = unplaced).
    pub home: Option<usize>,
    /// Extra exclusive-bus cycles to execute off the home plane.
    pub move_cycles: u64,
}

impl PlacedTask {
    /// An unplaced (ad-hoc) task: runs on any plane without a move.
    pub fn adhoc(phase: TaskPhase) -> Self {
        PlacedTask {
            phase,
            home: None,
            move_cycles: 0,
        }
    }
}

/// Schedules a sequence of (load, exec) tasks on one device.
#[derive(Debug, Default)]
pub struct OverlapScheduler;

impl OverlapScheduler {
    /// Serial makespan: no overlap — every cycle is exclusive-or-concurrent.
    pub fn makespan_serial(tasks: &[TaskPhase]) -> u64 {
        tasks.iter().map(|t| t.load_cycles + t.exec_cycles).sum()
    }

    /// Overlapped makespan: task k+1's load streams while task k executes
    /// (the classic two-stage pipeline bound).
    pub fn makespan_overlapped(tasks: &[TaskPhase]) -> u64 {
        if tasks.is_empty() {
            return 0;
        }
        // Pipeline recurrence: finish_load[k] = max(finish_load[k-1],
        // finish_exec[k-1] is NOT required — loads only need the bus) ...
        // loads are serialized on the exclusive bus; exec k starts after
        // its load and after exec k-1 (one concurrent bus).
        let mut load_done = 0u64;
        let mut exec_done = 0u64;
        for t in tasks {
            load_done += t.load_cycles;
            exec_done = load_done.max(exec_done) + t.exec_cycles;
        }
        exec_done
    }

    /// §8's dedicated DMA bus: loads cost nothing on the shared system bus
    /// (they still serialize among themselves), so the makespan approaches
    /// the pure-execution bound once loads are covered.
    pub fn makespan_with_dma(tasks: &[TaskPhase], dma_speedup: u64) -> u64 {
        let scaled: Vec<TaskPhase> = tasks
            .iter()
            .map(|t| TaskPhase {
                load_cycles: t.load_cycles / dma_speedup.max(1),
                exec_cycles: t.exec_cycles,
            })
            .collect();
        Self::makespan_overlapped(&scaled)
    }

    /// Multi-plane makespan: schedule the tasks across `planes` PE
    /// planes, each running its own load/exec pipeline. Picks the better
    /// of a greedy earliest-finish assignment and the home-partition
    /// assignment (every task on its home plane, move-free) — the latter
    /// guarantees the result never exceeds
    /// [`OverlapScheduler::makespan_overlapped`] on the same phases, and
    /// one plane reproduces it exactly.
    pub fn makespan_multi(tasks: &[PlacedTask], planes: usize) -> u64 {
        let planes = planes.max(1);
        let greedy = Self::greedy_assign(tasks, planes, 1);
        let home = Self::home_assign(tasks, planes);
        Self::finish(tasks, &greedy, planes, 1).min(Self::finish(tasks, &home, planes, 1))
    }

    /// Multi-plane makespan with the §8 DMA side bus carrying the load
    /// phases (`dma_speedup` divides every load; 0 and 1 both mean the
    /// side bus is off). The candidate set re-evaluates the no-DMA
    /// assignments under DMA, so the result never exceeds
    /// [`OverlapScheduler::makespan_multi`] on the same tasks.
    pub fn makespan_multi_with_dma(tasks: &[PlacedTask], planes: usize, dma_speedup: u64) -> u64 {
        let planes = planes.max(1);
        let candidates = [
            Self::greedy_assign(tasks, planes, dma_speedup),
            Self::greedy_assign(tasks, planes, 1),
            Self::home_assign(tasks, planes),
        ];
        candidates
            .iter()
            .map(|a| Self::finish(tasks, a, planes, dma_speedup))
            .min()
            .unwrap_or(0)
    }

    /// Every task on its home plane (unplaced tasks on plane 0): each
    /// plane then runs a move-free subsequence of the original order, and
    /// the pipeline recurrence is monotone under dropping tasks, so no
    /// plane finishes later than the single-plane schedule.
    fn home_assign(tasks: &[PlacedTask], planes: usize) -> Vec<usize> {
        tasks
            .iter()
            .map(|t| t.home.unwrap_or(0).min(planes - 1))
            .collect()
    }

    /// Greedy earliest-finish assignment: each task (in order) goes to
    /// the plane where it would finish soonest, move cost and DMA scaling
    /// included; ties go to the lowest plane id. Deterministic.
    fn greedy_assign(tasks: &[PlacedTask], planes: usize, dma_speedup: u64) -> Vec<usize> {
        let dma = dma_speedup.max(1);
        let mut load_done = vec![0u64; planes];
        let mut exec_done = vec![0u64; planes];
        let mut assign = Vec::with_capacity(tasks.len());
        for t in tasks {
            let mut best = 0usize;
            let mut best_finish = u64::MAX;
            for p in 0..planes {
                let ld = load_done[p] + Self::effective_load(t, p) / dma;
                let fin = ld.max(exec_done[p]) + t.phase.exec_cycles;
                if fin < best_finish {
                    best_finish = fin;
                    best = p;
                }
            }
            load_done[best] += Self::effective_load(t, best) / dma;
            exec_done[best] = load_done[best].max(exec_done[best]) + t.phase.exec_cycles;
            assign.push(best);
        }
        assign
    }

    /// Finish time of one fixed assignment: per-plane pipeline
    /// recurrences, off-home moves added to the load phase, DMA dividing
    /// every load. Monotone in `dma_speedup`, so re-evaluating a no-DMA
    /// assignment under DMA never increases its makespan.
    fn finish(tasks: &[PlacedTask], assign: &[usize], planes: usize, dma_speedup: u64) -> u64 {
        let dma = dma_speedup.max(1);
        let mut load_done = vec![0u64; planes];
        let mut exec_done = vec![0u64; planes];
        for (t, &p) in tasks.iter().zip(assign) {
            load_done[p] += Self::effective_load(t, p) / dma;
            exec_done[p] = load_done[p].max(exec_done[p]) + t.phase.exec_cycles;
        }
        exec_done.into_iter().max().unwrap_or(0)
    }

    /// Load cycles of `t` when executed on plane `p`: the task's own load
    /// plus the cross-plane move when `p` is not its home.
    fn effective_load(t: &PlacedTask, p: usize) -> u64 {
        let moved = t.home.is_some_and(|h| h != p);
        t.phase.load_cycles + if moved { t.move_cycles } else { 0 }
    }

    /// Overlap efficiency: serial / overlapped (1.0 = no gain, →2.0 for
    /// balanced phases).
    pub fn efficiency(tasks: &[TaskPhase]) -> f64 {
        let s = Self::makespan_serial(tasks);
        let o = Self::makespan_overlapped(tasks);
        if o == 0 {
            1.0
        } else {
            s as f64 / o as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_single() {
        assert_eq!(OverlapScheduler::makespan_serial(&[]), 0);
        assert_eq!(OverlapScheduler::makespan_overlapped(&[]), 0);
        let one = [TaskPhase {
            load_cycles: 10,
            exec_cycles: 5,
        }];
        // A single task cannot overlap with anything.
        assert_eq!(OverlapScheduler::makespan_overlapped(&one), 15);
    }

    #[test]
    fn balanced_pipeline_approaches_2x() {
        let tasks: Vec<TaskPhase> = (0..100)
            .map(|_| TaskPhase {
                load_cycles: 10,
                exec_cycles: 10,
            })
            .collect();
        let eff = OverlapScheduler::efficiency(&tasks);
        assert!(eff > 1.8, "balanced overlap should approach 2x: {eff}");
    }

    #[test]
    fn bottleneck_side_dominates() {
        let tasks: Vec<TaskPhase> = (0..50)
            .map(|_| TaskPhase {
                load_cycles: 100,
                exec_cycles: 1,
            })
            .collect();
        let o = OverlapScheduler::makespan_overlapped(&tasks);
        assert!(o >= 50 * 100, "load-bound: makespan ~ total load");
        assert!(o <= 50 * 100 + 10);
    }

    fn placed(load: u64, exec: u64, home: usize) -> PlacedTask {
        PlacedTask {
            phase: TaskPhase {
                load_cycles: load,
                exec_cycles: exec,
            },
            home: Some(home),
            move_cycles: 50,
        }
    }

    #[test]
    fn one_plane_reproduces_the_single_plane_schedule() {
        let tasks: Vec<PlacedTask> = (0..20).map(|i| placed(10 + i % 7, 5 + i % 5, 0)).collect();
        let phases: Vec<TaskPhase> = tasks.iter().map(|t| t.phase).collect();
        assert_eq!(
            OverlapScheduler::makespan_multi(&tasks, 1),
            OverlapScheduler::makespan_overlapped(&phases)
        );
        assert_eq!(
            OverlapScheduler::makespan_multi_with_dma(&tasks, 1, 8),
            OverlapScheduler::makespan_with_dma(&phases, 8)
        );
    }

    #[test]
    fn multi_plane_never_loses_and_splits_balanced_homes() {
        // Tasks alternate between two home planes with equal costs: two
        // planes must run them genuinely in parallel.
        let tasks: Vec<PlacedTask> = (0..10).map(|i| placed(100, 100, i % 2)).collect();
        let phases: Vec<TaskPhase> = tasks.iter().map(|t| t.phase).collect();
        let single = OverlapScheduler::makespan_overlapped(&phases);
        let multi = OverlapScheduler::makespan_multi(&tasks, 2);
        assert!(multi < single, "balanced two-home workload must split: {multi} vs {single}");
        // The DMA side bus can only help further.
        let dma = OverlapScheduler::makespan_multi_with_dma(&tasks, 2, 16);
        assert!(dma <= multi, "{dma} vs {multi}");
    }

    #[test]
    fn prohibitive_moves_fall_back_to_the_home_partition() {
        let mut tasks: Vec<PlacedTask> = (0..8).map(|i| placed(10, 90, i % 2)).collect();
        for t in &mut tasks {
            t.move_cycles = 1_000_000;
        }
        // The schedule never pays a move it did not have to: the home
        // partition is always in the candidate set.
        let multi = OverlapScheduler::makespan_multi(&tasks, 2);
        assert!(multi < 1_000_000, "{multi}");
        let phases: Vec<TaskPhase> = tasks.iter().map(|t| t.phase).collect();
        assert!(multi <= OverlapScheduler::makespan_overlapped(&phases));
    }

    #[test]
    fn adhoc_tasks_fill_idle_planes() {
        // Residents all homed on plane 0 plus ad-hoc compute: the greedy
        // assignment sends the ad-hoc tasks (which move for free) to the
        // idle plane and beats the single-plane schedule.
        let mut tasks: Vec<PlacedTask> = (0..6).map(|_| placed(50, 50, 0)).collect();
        for _ in 0..6 {
            tasks.push(PlacedTask::adhoc(TaskPhase {
                load_cycles: 50,
                exec_cycles: 50,
            }));
        }
        let phases: Vec<TaskPhase> = tasks.iter().map(|t| t.phase).collect();
        let single = OverlapScheduler::makespan_overlapped(&phases);
        let multi = OverlapScheduler::makespan_multi(&tasks, 2);
        assert!(multi < single, "{multi} vs {single}");
    }

    #[test]
    fn dma_bus_removes_load_bottleneck() {
        let tasks: Vec<TaskPhase> = (0..50)
            .map(|_| TaskPhase {
                load_cycles: 100,
                exec_cycles: 10,
            })
            .collect();
        let plain = OverlapScheduler::makespan_overlapped(&tasks);
        let dma = OverlapScheduler::makespan_with_dma(&tasks, 16);
        assert!(dma * 5 < plain, "16x DMA should slash the makespan");
    }
}
