//! Overlap scheduler (§3.1, §8 — E18).
//!
//! A CPM's concurrent bus and exclusive bus are independent: while one
//! task's registers are driven by broadcast instructions, another task's
//! data can stream in through addressed writes. This scheduler models a
//! two-phase task pipeline (load → execute) and computes the makespan
//! with and without overlap, plus the §8 DMA-bus variant where loads go
//! through a dedicated side bus.

/// One task's device-cycle demands.
#[derive(Debug, Clone, Copy)]
pub struct TaskPhase {
    /// Exclusive-bus cycles to load the task's data.
    pub load_cycles: u64,
    /// Concurrent-bus cycles to execute it.
    pub exec_cycles: u64,
}

/// Schedules a sequence of (load, exec) tasks on one device.
#[derive(Debug, Default)]
pub struct OverlapScheduler;

impl OverlapScheduler {
    /// Serial makespan: no overlap — every cycle is exclusive-or-concurrent.
    pub fn makespan_serial(tasks: &[TaskPhase]) -> u64 {
        tasks.iter().map(|t| t.load_cycles + t.exec_cycles).sum()
    }

    /// Overlapped makespan: task k+1's load streams while task k executes
    /// (the classic two-stage pipeline bound).
    pub fn makespan_overlapped(tasks: &[TaskPhase]) -> u64 {
        if tasks.is_empty() {
            return 0;
        }
        // Pipeline recurrence: finish_load[k] = max(finish_load[k-1],
        // finish_exec[k-1] is NOT required — loads only need the bus) ...
        // loads are serialized on the exclusive bus; exec k starts after
        // its load and after exec k-1 (one concurrent bus).
        let mut load_done = 0u64;
        let mut exec_done = 0u64;
        for t in tasks {
            load_done += t.load_cycles;
            exec_done = load_done.max(exec_done) + t.exec_cycles;
        }
        exec_done
    }

    /// §8's dedicated DMA bus: loads cost nothing on the shared system bus
    /// (they still serialize among themselves), so the makespan approaches
    /// the pure-execution bound once loads are covered.
    pub fn makespan_with_dma(tasks: &[TaskPhase], dma_speedup: u64) -> u64 {
        let scaled: Vec<TaskPhase> = tasks
            .iter()
            .map(|t| TaskPhase {
                load_cycles: t.load_cycles / dma_speedup.max(1),
                exec_cycles: t.exec_cycles,
            })
            .collect();
        Self::makespan_overlapped(&scaled)
    }

    /// Overlap efficiency: serial / overlapped (1.0 = no gain, →2.0 for
    /// balanced phases).
    pub fn efficiency(tasks: &[TaskPhase]) -> f64 {
        let s = Self::makespan_serial(tasks);
        let o = Self::makespan_overlapped(tasks);
        if o == 0 {
            1.0
        } else {
            s as f64 / o as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_single() {
        assert_eq!(OverlapScheduler::makespan_serial(&[]), 0);
        assert_eq!(OverlapScheduler::makespan_overlapped(&[]), 0);
        let one = [TaskPhase {
            load_cycles: 10,
            exec_cycles: 5,
        }];
        // A single task cannot overlap with anything.
        assert_eq!(OverlapScheduler::makespan_overlapped(&one), 15);
    }

    #[test]
    fn balanced_pipeline_approaches_2x() {
        let tasks: Vec<TaskPhase> = (0..100)
            .map(|_| TaskPhase {
                load_cycles: 10,
                exec_cycles: 10,
            })
            .collect();
        let eff = OverlapScheduler::efficiency(&tasks);
        assert!(eff > 1.8, "balanced overlap should approach 2x: {eff}");
    }

    #[test]
    fn bottleneck_side_dominates() {
        let tasks: Vec<TaskPhase> = (0..50)
            .map(|_| TaskPhase {
                load_cycles: 100,
                exec_cycles: 1,
            })
            .collect();
        let o = OverlapScheduler::makespan_overlapped(&tasks);
        assert!(o >= 50 * 100, "load-bound: makespan ~ total load");
        assert!(o <= 50 * 100 + 10);
    }

    #[test]
    fn dma_bus_removes_load_bottleneck() {
        let tasks: Vec<TaskPhase> = (0..50)
            .map(|_| TaskPhase {
                load_cycles: 100,
                exec_cycles: 10,
            })
            .collect();
        let plain = OverlapScheduler::makespan_overlapped(&tasks);
        let dma = OverlapScheduler::makespan_with_dma(&tasks, 16);
        assert!(dma * 5 < plain, "16x DMA should slash the makespan");
    }
}
