//! Cycle accounting — the paper's evaluation metric.
//!
//! The paper's claims are *instruction cycle counts*: `~1` for universal
//! operations, `~M` for local operations, `~√N` for global operations
//! (abstract §1). We count them in two granularities plus the system-bus
//! traffic the paper argues CPM eliminates (§2):
//!
//! * `macro_cycles` — broadcast instructions on the concurrent bus; the unit
//!   the paper's formulas count (one register-level word op per cycle).
//! * `bit_cycles` — the bit-serial expansion of each macro op at the PE's
//!   word width (device fidelity; see DESIGN.md "ISA formalization").
//! * `exclusive_ops` — conventional addressed reads/writes through the
//!   exclusive bus (loads, readouts; Rule 2).
//! * `bus_words` — words crossing the shared system bus.

use std::ops::{Add, AddAssign};

/// Cost of work done by a CPM device.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ConcurrentCost {
    /// Broadcast instructions on the concurrent bus (paper's unit).
    pub macro_cycles: u64,
    /// Bit-serial expansion at the device word width.
    pub bit_cycles: u64,
    /// Addressed (exclusive-bus) reads/writes.
    pub exclusive_ops: u64,
    /// Words transferred over the shared system bus.
    pub bus_words: u64,
}

impl ConcurrentCost {
    /// Cost of `n` broadcast macro instructions expanding to `bits`
    /// bit-serial cycles in total.
    pub fn broadcast(n: u64, bits: u64) -> Self {
        ConcurrentCost {
            macro_cycles: n,
            bit_cycles: bits,
            ..Default::default()
        }
    }

    /// Cost of `n` exclusive (addressed) operations of one word each.
    pub fn exclusive(n: u64) -> Self {
        ConcurrentCost {
            exclusive_ops: n,
            bus_words: n,
            ..Default::default()
        }
    }

    /// Total device-cycle estimate when the concurrent bus and the exclusive
    /// bus are *not* overlapped (worst case; §3.1 notes they can overlap).
    pub fn serial_total(&self) -> u64 {
        self.macro_cycles + self.exclusive_ops
    }
}

impl Add for ConcurrentCost {
    type Output = ConcurrentCost;
    fn add(self, rhs: ConcurrentCost) -> ConcurrentCost {
        ConcurrentCost {
            macro_cycles: self.macro_cycles + rhs.macro_cycles,
            bit_cycles: self.bit_cycles + rhs.bit_cycles,
            exclusive_ops: self.exclusive_ops + rhs.exclusive_ops,
            bus_words: self.bus_words + rhs.bus_words,
        }
    }
}

impl AddAssign for ConcurrentCost {
    fn add_assign(&mut self, rhs: ConcurrentCost) {
        *self = *self + rhs;
    }
}

/// Cost of work done by the serial bus-sharing baseline (§2): a CPU that
/// must stream every word it touches over the system bus.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SerialCost {
    /// CPU instruction cycles (one simple ALU/branch op each).
    pub cpu_cycles: u64,
    /// Words moved over the system bus for *processing* purposes — the
    /// traffic the paper says CPM eliminates.
    pub bus_words: u64,
}

impl SerialCost {
    /// `n` CPU ops each touching one memory word over the bus.
    pub fn touching(n: u64) -> Self {
        SerialCost {
            cpu_cycles: n,
            bus_words: n,
        }
    }

    /// `n` pure register-register CPU ops (no bus traffic).
    pub fn compute(n: u64) -> Self {
        SerialCost {
            cpu_cycles: n,
            bus_words: 0,
        }
    }
}

impl Add for SerialCost {
    type Output = SerialCost;
    fn add(self, rhs: SerialCost) -> SerialCost {
        SerialCost {
            cpu_cycles: self.cpu_cycles + rhs.cpu_cycles,
            bus_words: self.bus_words + rhs.bus_words,
        }
    }
}

impl AddAssign for SerialCost {
    fn add_assign(&mut self, rhs: SerialCost) {
        *self = *self + rhs;
    }
}

/// A measured data point for one experiment configuration: the paper's
/// claimed formula value next to the measured cycle count.
#[derive(Debug, Clone)]
pub struct ClaimPoint {
    /// Workload descriptor, e.g. `"N=65536 M=256"`.
    pub config: String,
    /// Cycles the paper's formula predicts (`~` semantics: order, not exact).
    pub paper_formula: f64,
    /// Measured macro cycles on the simulator.
    pub measured: u64,
    /// Serial-baseline cost for the same operation, if applicable.
    pub baseline: Option<u64>,
}

impl ClaimPoint {
    /// measured / formula — should be Θ(1) across a sweep if the claim holds.
    pub fn ratio(&self) -> f64 {
        self.measured as f64 / self.paper_formula.max(1.0)
    }

    /// baseline / measured — the speedup the paper advertises.
    pub fn speedup(&self) -> Option<f64> {
        self.baseline
            .map(|b| b as f64 / (self.measured.max(1)) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrent_cost_adds() {
        let a = ConcurrentCost::broadcast(3, 24) + ConcurrentCost::exclusive(2);
        assert_eq!(a.macro_cycles, 3);
        assert_eq!(a.bit_cycles, 24);
        assert_eq!(a.exclusive_ops, 2);
        assert_eq!(a.bus_words, 2);
        assert_eq!(a.serial_total(), 5);
    }

    #[test]
    fn serial_cost_adds() {
        let c = SerialCost::touching(10) + SerialCost::compute(5);
        assert_eq!(c.cpu_cycles, 15);
        assert_eq!(c.bus_words, 10);
    }

    #[test]
    fn claim_point_ratio_and_speedup() {
        let p = ClaimPoint {
            config: "N=1024".into(),
            paper_formula: 64.0,
            measured: 128,
            baseline: Some(1024),
        };
        assert!((p.ratio() - 2.0).abs() < 1e-9);
        assert!((p.speedup().unwrap() - 8.0).abs() < 1e-9);
    }
}
