//! Trace-execution backends for the computable-memory PE plane.
//!
//! Two interchangeable backends share one API (`new` / `load_trace` /
//! `load_step` / `available_traces` / `pick_shape` / `run_step` /
//! `run_trace` / `run_chained` and a public `dispatches` counter):
//!
//! * [`TraceInterpreter`] — the default: a pure-Rust executor that decodes
//!   wire-format instruction words and steps them through the
//!   [`WordEngine`](crate::device::computable::WordEngine)
//!   (sharded across threads per [`ExecConfig`]). Dependency-free and offline; it honors the same
//!   dispatch-window discipline (pad-to-T, chain windows) as the compiled
//!   backend, so the dispatch-amortization accounting stays comparable.
//! * [`pjrt::PjrtBackend`] (feature `pjrt`) — loads the AOT-compiled
//!   JAX/Pallas artifacts produced by `python/compile/aot.py` and executes
//!   them through XLA's PJRT CPU client. Python runs only at build time
//!   (`make artifacts`); see `src/runtime/pjrt.rs`.
//!
//! [`Backend`] aliases whichever backend the feature set selects, so
//! callers (CLI `runtime-check`, `benches/paper.rs` E19, the
//! engine-equivalence tests) are written once against the shared API.
#![warn(missing_docs)]

use std::path::{Path, PathBuf};

use crate::device::computable::isa::{Instr, INSTR_WIDTH, N_REGS};
use crate::device::computable::{ExecConfig, PePlane, Reg, SpawnMode, WordExec};
use crate::error::{CpmError, Result};

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

/// The backend selected by the current feature set.
#[cfg(feature = "pjrt")]
pub type Backend = pjrt::PjrtBackend;
/// The backend selected by the current feature set.
#[cfg(not(feature = "pjrt"))]
pub type Backend = TraceInterpreter;

/// Trace-executable variants (PE-plane width × dispatch-window length).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceShape {
    /// PE-plane width.
    pub p: usize,
    /// Trace length per dispatch.
    pub t: usize,
}

impl TraceShape {
    /// Pick the smallest shape fitting `p` PEs, preferring the largest
    /// trace window for dispatch amortization.
    pub fn pick(shapes: &[TraceShape], p: usize) -> Option<TraceShape> {
        shapes
            .iter()
            .copied()
            .filter(|s| s.p >= p)
            .min_by_key(|s| (s.p, usize::MAX - s.t))
    }
}

/// Probe an artifact directory for `pe_trace_p{P}_t{T}.hlo.txt` files.
pub(crate) fn probe_artifact_traces(dir: &Path) -> Vec<TraceShape> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for entry in rd.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(rest) = name
                .strip_prefix("pe_trace_p")
                .and_then(|r| r.strip_suffix(".hlo.txt"))
            {
                if let Some((p, t)) = rest.split_once("_t") {
                    if let (Ok(p), Ok(t)) = (p.parse(), t.parse()) {
                        out.push(TraceShape { p, t });
                    }
                }
            }
        }
    }
    out.sort_by_key(|s| (s.p, s.t));
    out
}

/// Encode a trace into wire-format words, NOP-padded to a `t`-instruction
/// dispatch window (shared by every backend so padding can never diverge).
pub(crate) fn encode_window(trace: &[Instr], t: usize) -> Vec<i32> {
    assert!(trace.len() <= t, "trace longer than dispatch window");
    let mut words = Vec::with_capacity(t * INSTR_WIDTH);
    for instr in trace {
        words.extend_from_slice(&instr.encode());
    }
    // NOP padding (the all-zero word decodes to NOP).
    words.resize(t * INSTR_WIDTH, 0);
    words
}

/// Per-shard PE floor for the interpreter's step-at-a-time execution
/// under `SpawnMode::PerCall`: one scoped spawn/join per instruction
/// only pays off on planes well past the general [`ExecConfig`]
/// default. The persistent pool (the default spawn mode) drops the
/// per-step floor to a mailbox wake + epoch barrier (E22), so it keeps
/// the config's own floor instead.
const STEP_MIN_SHARD_PES: usize = 1 << 16;

/// Dispatch-window shapes the interpreter offers when no artifact
/// directory is present (it needs no artifacts — any shape executes).
const DEFAULT_TRACE_SHAPES: &[TraceShape] = &[
    TraceShape { p: 1024, t: 32 },
    TraceShape { p: 4096, t: 32 },
    TraceShape { p: 4096, t: 128 },
    TraceShape { p: 16384, t: 128 },
];

/// The pure-Rust trace executor (default backend).
///
/// Functionally it is the word plane the config's
/// [`ComputeBackend`](crate::device::computable::ComputeBackend)
/// constructs (so big planes parallelize, and `--backend` selects the
/// executor) driven through the compiled backend's
/// dispatch API: every instruction goes through the wire encoding
/// (`Instr::encode` → `Instr::decode`), traces are NOP-padded to the
/// shape's window length, and longer traces are chained window by window —
/// so swapping in the PJRT backend changes performance, not semantics.
#[derive(Debug)]
pub struct TraceInterpreter {
    dir: PathBuf,
    exec: ExecConfig,
    /// Dispatches issued (perf accounting; one per trace window or step).
    pub dispatches: u64,
}

impl TraceInterpreter {
    /// Create an interpreter rooted at the artifact directory (used only
    /// to advertise the same shapes a compiled backend would offer).
    pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
        Self::with_exec(artifact_dir, ExecConfig::default())
    }

    /// Interpreter with an explicit plane-execution policy: dispatch
    /// windows on big planes execute on the sharded plane.
    pub fn with_exec<P: AsRef<Path>>(artifact_dir: P, exec: ExecConfig) -> Result<Self> {
        Ok(TraceInterpreter {
            dir: artifact_dir.as_ref().to_path_buf(),
            exec,
            dispatches: 0,
        })
    }

    /// Change the plane-execution policy.
    pub fn set_exec(&mut self, exec: ExecConfig) {
        self.exec = exec;
    }

    /// Ensure the trace executable for `shape` is available (always is —
    /// the interpreter compiles nothing).
    pub fn load_trace(&mut self, shape: TraceShape) -> Result<()> {
        if shape.p == 0 || shape.t == 0 {
            return Err(CpmError::Runtime(format!(
                "degenerate trace shape p={} t={}",
                shape.p, shape.t
            )));
        }
        Ok(())
    }

    /// Ensure the single-step executable for plane width `p` is available.
    pub fn load_step(&mut self, p: usize) -> Result<()> {
        if p == 0 {
            return Err(CpmError::Runtime("degenerate plane width 0".into()));
        }
        Ok(())
    }

    /// Available trace shapes: the artifact directory's, or the default
    /// set when none exists.
    pub fn available_traces(&self) -> Vec<TraceShape> {
        let probed = probe_artifact_traces(&self.dir);
        if probed.is_empty() {
            DEFAULT_TRACE_SHAPES.to_vec()
        } else {
            probed
        }
    }

    /// Pick the smallest shape fitting `p` PEs (largest window preferred).
    pub fn pick_shape(&self, p: usize) -> Option<TraceShape> {
        TraceShape::pick(&self.available_traces(), p)
    }

    fn exec_words(
        &mut self,
        p: usize,
        state: &[i32],
        words: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        assert_eq!(state.len(), N_REGS * p);
        // The dispatch API requires a match count after *every*
        // instruction, so the window executes step by step. Under the
        // persistent worker pool (the default) a parallel step costs a
        // wake + epoch barrier, so the config's own shard floor stands —
        // and the pool handle is shared with the clone, so every window
        // reuses the same parked workers for the interpreter's lifetime.
        // Spawn-per-call pays a thread spawn/join per step instead:
        // raise its floor so sharding only engages where one step
        // outweighs that orchestration cost.
        let exec = match self.exec.spawn {
            SpawnMode::Persistent => self.exec.clone(),
            SpawnMode::PerCall => {
                let floor = self.exec.min_shard_pes.max(STEP_MIN_SHARD_PES);
                self.exec.clone().min_shard_pes(floor)
            }
        };
        // Plane construction goes through the ComputeBackend seam: the
        // config's backend kind decides what actually executes.
        let mut engine = exec.compute_backend().word_plane(p, 32);
        engine.set_state(state);
        let mut counts = Vec::with_capacity(words.len() / INSTR_WIDTH);
        for chunk in words.chunks_exact(INSTR_WIDTH) {
            let mut buf = [0i32; INSTR_WIDTH];
            buf.copy_from_slice(chunk);
            let instr = Instr::decode(&buf).ok_or_else(|| {
                CpmError::Runtime(format!("undecodable instruction word {buf:?}"))
            })?;
            engine.step(&instr);
            counts.push(engine.plane(Reg::M).iter().filter(|&&m| m != 0).count() as i32);
        }
        self.dispatches += 1;
        Ok((engine.state(), counts))
    }

    /// Execute one step: `state` is `i32[N_REGS * p]` row-major planes.
    pub fn run_step(&mut self, p: usize, state: &[i32], instr: &Instr) -> Result<Vec<i32>> {
        self.load_step(p)?;
        let (final_state, _) = self.exec_words(p, state, &instr.encode())?;
        Ok(final_state)
    }

    /// Execute a whole trace of up to the shape's T instructions (shorter
    /// traces are padded with NOPs). Returns `(final_state, match_counts)`
    /// with one match count per window position.
    pub fn run_trace(
        &mut self,
        shape: TraceShape,
        state: &[i32],
        trace: &[Instr],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        self.load_trace(shape)?;
        assert_eq!(state.len(), N_REGS * shape.p);
        let words = encode_window(trace, shape.t);
        self.exec_words(shape.p, state, &words)
    }

    /// Run an arbitrary-length trace by chaining dispatch windows.
    pub fn run_chained(
        &mut self,
        shape: TraceShape,
        state: &[i32],
        trace: &[Instr],
    ) -> Result<Vec<i32>> {
        let mut cur = state.to_vec();
        for chunk in trace.chunks(shape.t.max(1)) {
            let (next, _) = self.run_trace(shape, &cur, chunk)?;
            cur = next;
        }
        Ok(cur)
    }
}

/// Pad a word-engine state (`N_REGS * p`) out to a larger plane width.
pub fn pad_state(state: &[i32], p: usize, target_p: usize) -> Vec<i32> {
    assert_eq!(state.len(), N_REGS * p);
    assert!(target_p >= p);
    let mut out = vec![0i32; N_REGS * target_p];
    for r in 0..N_REGS {
        out[r * target_p..r * target_p + p].copy_from_slice(&state[r * p..(r + 1) * p]);
    }
    out
}

/// Slice a padded state back down to `p` PEs.
pub fn unpad_state(state: &[i32], target_p: usize, p: usize) -> Vec<i32> {
    assert_eq!(state.len(), N_REGS * target_p);
    let mut out = vec![0i32; N_REGS * p];
    for r in 0..N_REGS {
        out[r * p..(r + 1) * p].copy_from_slice(&state[r * target_p..r * target_p + p]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::computable::isa::Opcode;
    use crate::device::computable::{Src, WordEngine};

    #[test]
    fn pad_unpad_roundtrip() {
        let p = 3;
        let state: Vec<i32> = (0..(N_REGS * p) as i32).collect();
        let padded = pad_state(&state, p, 8);
        assert_eq!(padded.len(), N_REGS * 8);
        assert_eq!(unpad_state(&padded, 8, p), state);
        // padding is zero
        assert_eq!(padded[3], 0);
    }

    #[test]
    fn shape_pick_prefers_smallest_fit_largest_window() {
        let shapes = [
            TraceShape { p: 1024, t: 32 },
            TraceShape { p: 4096, t: 32 },
            TraceShape { p: 4096, t: 128 },
        ];
        assert_eq!(
            TraceShape::pick(&shapes, 1000),
            Some(TraceShape { p: 1024, t: 32 })
        );
        assert_eq!(
            TraceShape::pick(&shapes, 2048),
            Some(TraceShape { p: 4096, t: 128 })
        );
        assert_eq!(TraceShape::pick(&shapes, 1 << 20), None);
    }

    #[test]
    fn interpreter_matches_word_engine_through_the_wire_format() {
        let p = 16;
        let mut interp = TraceInterpreter::new("no-such-dir").unwrap();
        let shape = interp.pick_shape(p).unwrap();
        let mut small = WordEngine::new(p, 32);
        small.load_plane(Reg::Nb, &(0..p as i32).collect::<Vec<_>>());
        let state = pad_state(&small.state(), p, shape.p);
        let trace = vec![
            Instr::all(Opcode::Copy, Src::Reg(Reg::Nb), Reg::Op),
            Instr::all(Opcode::Add, Src::Left, Reg::Op),
            Instr::all(Opcode::CmpGt, Src::Imm, Reg::Op).imm(5),
        ];
        let (got, counts) = interp.run_trace(shape, &state, &trace).unwrap();
        let mut word = WordEngine::new(shape.p, 32);
        word.set_state(&state);
        word.run(&trace);
        assert_eq!(got, word.state());
        assert_eq!(counts.len(), shape.t);
        assert_eq!(interp.dispatches, 1);
    }

    #[test]
    fn chained_windows_match_one_long_run() {
        let shape = TraceShape { p: 8, t: 4 };
        let mut interp = TraceInterpreter::new("no-such-dir").unwrap();
        let mut word = WordEngine::new(shape.p, 32);
        word.load_plane(Reg::Nb, &[5, -1, 7, 0, 3, 2, 9, -4]);
        let state = word.state();
        let trace: Vec<Instr> = (0..10)
            .map(|k| match k % 3 {
                0 => Instr::all(Opcode::Add, Src::Left, Reg::Op),
                1 => Instr::all(Opcode::Copy, Src::Reg(Reg::Op), Reg::Nb),
                _ => Instr::all(Opcode::Max, Src::Right, Reg::Op),
            })
            .collect();
        let chained = interp.run_chained(shape, &state, &trace).unwrap();
        word.run(&trace);
        assert_eq!(chained, word.state());
        assert_eq!(interp.dispatches, 3); // ceil(10 / 4) windows
    }

    #[test]
    fn degenerate_shapes_error() {
        let mut interp = TraceInterpreter::new("no-such-dir").unwrap();
        assert!(interp.load_trace(TraceShape { p: 0, t: 8 }).is_err());
        assert!(interp.load_step(0).is_err());
    }
}
