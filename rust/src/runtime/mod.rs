//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and execute
//! PE-plane traces through XLA.
//!
//! Python runs only at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 trace model (whose inner step is the L1 Pallas kernel) to
//! HLO **text**, and this module loads it with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client and
//! executes it from the request path — Python is never on the hot path.
//!
//! Artifacts (see `artifacts/manifest.json`):
//! * `pe_step_p{P}.hlo.txt` — one concurrent cycle over a P-PE plane,
//! * `pe_trace_p{P}_t{T}.hlo.txt` — a `lax.scan` over T instruction words
//!   (one PJRT dispatch per T cycles — the dispatch amortization).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::device::computable::isa::{Instr, INSTR_WIDTH, N_REGS};
use crate::error::{CpmError, Result};

/// Trace-executable variants available in the artifact directory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceShape {
    /// PE-plane width.
    pub p: usize,
    /// Trace length per dispatch.
    pub t: usize,
}

/// The PJRT backend: a CPU client plus compiled executables per shape.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    traces: HashMap<TraceShape, xla::PjRtLoadedExecutable>,
    steps: HashMap<usize, xla::PjRtLoadedExecutable>,
    /// PJRT dispatches issued (perf accounting).
    pub dispatches: u64,
}

impl std::fmt::Debug for PjrtBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtBackend")
            .field("dir", &self.dir)
            .field("traces", &self.traces.keys().collect::<Vec<_>>())
            .field("steps", &self.steps.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl PjrtBackend {
    /// Create a CPU PJRT client rooted at the artifact directory.
    pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| CpmError::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(PjrtBackend {
            client,
            dir: artifact_dir.as_ref().to_path_buf(),
            traces: HashMap::new(),
            steps: HashMap::new(),
            dispatches: 0,
        })
    }

    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| CpmError::Runtime("non-utf8 artifact path".into()))?,
        )
        .map_err(|e| CpmError::Runtime(format!("parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| CpmError::Runtime(format!("compile {path:?}: {e}")))
    }

    /// Ensure the trace executable for `shape` is compiled and cached.
    pub fn load_trace(&mut self, shape: TraceShape) -> Result<()> {
        if self.traces.contains_key(&shape) {
            return Ok(());
        }
        let path = self
            .dir
            .join(format!("pe_trace_p{}_t{}.hlo.txt", shape.p, shape.t));
        let exe = self.compile(&path)?;
        self.traces.insert(shape, exe);
        Ok(())
    }

    /// Ensure the single-step executable for plane width `p` is cached.
    pub fn load_step(&mut self, p: usize) -> Result<()> {
        if self.steps.contains_key(&p) {
            return Ok(());
        }
        let path = self.dir.join(format!("pe_step_p{p}.hlo.txt"));
        let exe = self.compile(&path)?;
        self.steps.insert(p, exe);
        Ok(())
    }

    /// Available trace shapes by probing the artifact directory.
    pub fn available_traces(&self) -> Vec<TraceShape> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for entry in rd.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if let Some(rest) = name
                    .strip_prefix("pe_trace_p")
                    .and_then(|r| r.strip_suffix(".hlo.txt"))
                {
                    if let Some((p, t)) = rest.split_once("_t") {
                        if let (Ok(p), Ok(t)) = (p.parse(), t.parse()) {
                            out.push(TraceShape { p, t });
                        }
                    }
                }
            }
        }
        out.sort_by_key(|s| (s.p, s.t));
        out
    }

    /// Pick the smallest artifact shape fitting `p` PEs, preferring the
    /// largest trace window for dispatch amortization.
    pub fn pick_shape(&self, p: usize) -> Option<TraceShape> {
        self.available_traces()
            .into_iter()
            .filter(|s| s.p >= p)
            .min_by_key(|s| (s.p, usize::MAX - s.t))
    }

    /// Execute one step: `state` is `i32[N_REGS * p]` row-major planes.
    pub fn run_step(&mut self, p: usize, state: &[i32], instr: &Instr) -> Result<Vec<i32>> {
        self.load_step(p)?;
        let exe = &self.steps[&p];
        assert_eq!(state.len(), N_REGS * p);
        let st = xla::Literal::vec1(state)
            .reshape(&[N_REGS as i64, p as i64])
            .map_err(|e| CpmError::Runtime(format!("reshape state: {e}")))?;
        let iw = instr.encode();
        let il = xla::Literal::vec1(&iw[..]);
        self.dispatches += 1;
        let result = exe
            .execute::<xla::Literal>(&[st, il])
            .map_err(|e| CpmError::Runtime(format!("execute step: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| CpmError::Runtime(format!("sync: {e}")))?;
        let out = result
            .to_tuple1()
            .map_err(|e| CpmError::Runtime(format!("tuple: {e}")))?;
        out.to_vec::<i32>()
            .map_err(|e| CpmError::Runtime(format!("to_vec: {e}")))
    }

    /// Execute a whole trace of up to the shape's T instructions (shorter
    /// traces are padded with NOPs). Returns `(final_state, match_counts)`.
    pub fn run_trace(
        &mut self,
        shape: TraceShape,
        state: &[i32],
        trace: &[Instr],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        self.load_trace(shape)?;
        assert_eq!(state.len(), N_REGS * shape.p);
        assert!(trace.len() <= shape.t, "trace longer than artifact window");
        let mut words = Vec::with_capacity(shape.t * INSTR_WIDTH);
        for instr in trace {
            words.extend_from_slice(&instr.encode());
        }
        // NOP padding.
        words.resize(shape.t * INSTR_WIDTH, 0);
        let st = xla::Literal::vec1(state)
            .reshape(&[N_REGS as i64, shape.p as i64])
            .map_err(|e| CpmError::Runtime(format!("reshape state: {e}")))?;
        let tr = xla::Literal::vec1(&words)
            .reshape(&[shape.t as i64, INSTR_WIDTH as i64])
            .map_err(|e| CpmError::Runtime(format!("reshape trace: {e}")))?;
        let exe = &self.traces[&shape];
        self.dispatches += 1;
        let result = exe
            .execute::<xla::Literal>(&[st, tr])
            .map_err(|e| CpmError::Runtime(format!("execute trace: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| CpmError::Runtime(format!("sync: {e}")))?;
        let (final_state, counts) = result
            .to_tuple2()
            .map_err(|e| CpmError::Runtime(format!("tuple2: {e}")))?;
        Ok((
            final_state
                .to_vec::<i32>()
                .map_err(|e| CpmError::Runtime(format!("state vec: {e}")))?,
            counts
                .to_vec::<i32>()
                .map_err(|e| CpmError::Runtime(format!("counts vec: {e}")))?,
        ))
    }

    /// Run an arbitrary-length trace by chaining dispatch windows.
    pub fn run_chained(
        &mut self,
        shape: TraceShape,
        state: &[i32],
        trace: &[Instr],
    ) -> Result<Vec<i32>> {
        let mut cur = state.to_vec();
        for chunk in trace.chunks(shape.t.max(1)) {
            let (next, _) = self.run_trace(shape, &cur, chunk)?;
            cur = next;
        }
        Ok(cur)
    }
}

/// Pad a word-engine state (`N_REGS * p`) out to a larger plane width.
pub fn pad_state(state: &[i32], p: usize, target_p: usize) -> Vec<i32> {
    assert_eq!(state.len(), N_REGS * p);
    assert!(target_p >= p);
    let mut out = vec![0i32; N_REGS * target_p];
    for r in 0..N_REGS {
        out[r * target_p..r * target_p + p].copy_from_slice(&state[r * p..(r + 1) * p]);
    }
    out
}

/// Slice a padded state back down to `p` PEs.
pub fn unpad_state(state: &[i32], target_p: usize, p: usize) -> Vec<i32> {
    assert_eq!(state.len(), N_REGS * target_p);
    let mut out = vec![0i32; N_REGS * p];
    for r in 0..N_REGS {
        out[r * p..(r + 1) * p].copy_from_slice(&state[r * target_p..r * target_p + p]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_unpad_roundtrip() {
        let p = 3;
        let state: Vec<i32> = (0..(N_REGS * p) as i32).collect();
        let padded = pad_state(&state, p, 8);
        assert_eq!(padded.len(), N_REGS * 8);
        assert_eq!(unpad_state(&padded, 8, p), state);
        // padding is zero
        assert_eq!(padded[3], 0);
    }
}
