//! PJRT backend (feature `pjrt`): load the AOT-compiled JAX/Pallas
//! artifacts and execute PE-plane traces through XLA.
//!
//! Python runs only at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 trace model (whose inner step is the L1 Pallas kernel) to
//! HLO **text**, and this module loads it with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client and
//! executes it from the request path — Python is never on the hot path.
//!
//! Building with this feature requires the `xla` crate (Rust bindings to
//! xla_extension); it is not part of the offline default build — add it as
//! a vendored/path dependency before enabling `--features pjrt`.
//!
//! Artifacts (see `artifacts/manifest.json`):
//! * `pe_step_p{P}.hlo.txt` — one concurrent cycle over a P-PE plane,
//! * `pe_trace_p{P}_t{T}.hlo.txt` — a `lax.scan` over T instruction words
//!   (one PJRT dispatch per T cycles — the dispatch amortization).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::{probe_artifact_traces, TraceShape};
use crate::device::computable::isa::{Instr, INSTR_WIDTH, N_REGS};
use crate::error::{CpmError, Result};

/// The PJRT backend: a CPU client plus compiled executables per shape.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    traces: HashMap<TraceShape, xla::PjRtLoadedExecutable>,
    steps: HashMap<usize, xla::PjRtLoadedExecutable>,
    /// PJRT dispatches issued (perf accounting).
    pub dispatches: u64,
}

impl std::fmt::Debug for PjrtBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtBackend")
            .field("dir", &self.dir)
            .field("traces", &self.traces.keys().collect::<Vec<_>>())
            .field("steps", &self.steps.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl PjrtBackend {
    /// Create a CPU PJRT client rooted at the artifact directory.
    pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| CpmError::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(PjrtBackend {
            client,
            dir: artifact_dir.as_ref().to_path_buf(),
            traces: HashMap::new(),
            steps: HashMap::new(),
            dispatches: 0,
        })
    }

    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| CpmError::Runtime("non-utf8 artifact path".into()))?,
        )
        .map_err(|e| CpmError::Runtime(format!("parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| CpmError::Runtime(format!("compile {path:?}: {e}")))
    }

    /// Ensure the trace executable for `shape` is compiled and cached.
    pub fn load_trace(&mut self, shape: TraceShape) -> Result<()> {
        if self.traces.contains_key(&shape) {
            return Ok(());
        }
        let path = self
            .dir
            .join(format!("pe_trace_p{}_t{}.hlo.txt", shape.p, shape.t));
        let exe = self.compile(&path)?;
        self.traces.insert(shape, exe);
        Ok(())
    }

    /// Ensure the single-step executable for plane width `p` is cached.
    pub fn load_step(&mut self, p: usize) -> Result<()> {
        if self.steps.contains_key(&p) {
            return Ok(());
        }
        let path = self.dir.join(format!("pe_step_p{p}.hlo.txt"));
        let exe = self.compile(&path)?;
        self.steps.insert(p, exe);
        Ok(())
    }

    /// Available trace shapes by probing the artifact directory.
    pub fn available_traces(&self) -> Vec<TraceShape> {
        probe_artifact_traces(&self.dir)
    }

    /// Pick the smallest artifact shape fitting `p` PEs, preferring the
    /// largest trace window for dispatch amortization.
    pub fn pick_shape(&self, p: usize) -> Option<TraceShape> {
        TraceShape::pick(&self.available_traces(), p)
    }

    /// Execute one step: `state` is `i32[N_REGS * p]` row-major planes.
    pub fn run_step(&mut self, p: usize, state: &[i32], instr: &Instr) -> Result<Vec<i32>> {
        self.load_step(p)?;
        let exe = &self.steps[&p];
        assert_eq!(state.len(), N_REGS * p);
        let st = xla::Literal::vec1(state)
            .reshape(&[N_REGS as i64, p as i64])
            .map_err(|e| CpmError::Runtime(format!("reshape state: {e}")))?;
        let iw = instr.encode();
        let il = xla::Literal::vec1(&iw[..]);
        self.dispatches += 1;
        let result = exe
            .execute::<xla::Literal>(&[st, il])
            .map_err(|e| CpmError::Runtime(format!("execute step: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| CpmError::Runtime(format!("sync: {e}")))?;
        let out = result
            .to_tuple1()
            .map_err(|e| CpmError::Runtime(format!("tuple: {e}")))?;
        out.to_vec::<i32>()
            .map_err(|e| CpmError::Runtime(format!("to_vec: {e}")))
    }

    /// Execute a whole trace of up to the shape's T instructions (shorter
    /// traces are padded with NOPs). Returns `(final_state, match_counts)`.
    pub fn run_trace(
        &mut self,
        shape: TraceShape,
        state: &[i32],
        trace: &[Instr],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        self.load_trace(shape)?;
        assert_eq!(state.len(), N_REGS * shape.p);
        let words = super::encode_window(trace, shape.t);
        let st = xla::Literal::vec1(state)
            .reshape(&[N_REGS as i64, shape.p as i64])
            .map_err(|e| CpmError::Runtime(format!("reshape state: {e}")))?;
        let tr = xla::Literal::vec1(&words)
            .reshape(&[shape.t as i64, INSTR_WIDTH as i64])
            .map_err(|e| CpmError::Runtime(format!("reshape trace: {e}")))?;
        let exe = &self.traces[&shape];
        self.dispatches += 1;
        let result = exe
            .execute::<xla::Literal>(&[st, tr])
            .map_err(|e| CpmError::Runtime(format!("execute trace: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| CpmError::Runtime(format!("sync: {e}")))?;
        let (final_state, counts) = result
            .to_tuple2()
            .map_err(|e| CpmError::Runtime(format!("tuple2: {e}")))?;
        Ok((
            final_state
                .to_vec::<i32>()
                .map_err(|e| CpmError::Runtime(format!("state vec: {e}")))?,
            counts
                .to_vec::<i32>()
                .map_err(|e| CpmError::Runtime(format!("counts vec: {e}")))?,
        ))
    }

    /// Run an arbitrary-length trace by chaining dispatch windows.
    pub fn run_chained(
        &mut self,
        shape: TraceShape,
        state: &[i32],
        trace: &[Instr],
    ) -> Result<Vec<i32>> {
        let mut cur = state.to_vec();
        for chunk in trace.chunks(shape.t.max(1)) {
            let (next, _) = self.run_trace(shape, &cur, chunk)?;
            cur = next;
        }
        Ok(cur)
    }
}
