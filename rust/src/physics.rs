//! Physical feasibility model (§8, Eq 8-1).
//!
//! The paper estimates the concurrent-bus routing-layer RC delay:
//!
//! ```text
//! delay = (4 · 8.8e-12 · L² / D) · (17e-9 / T) = 0.6e-18 · L² / D / T
//! ```
//!
//! with `L` the routing-layer span, `T` the copper thickness and `D` the
//! insulating-oxide thickness (SI meters), and derives: at D = 25 nm,
//! T = 10 nm a 1 GHz CPM can span L ≤ ~1.5 mm; a 4 GB content movable
//! memory fits ~15×15 mm²; with an output cache of depth 4 and a 400 MHz
//! system bus each routing layer runs at 100 MHz (E16).

/// Permittivity prefactor of Eq 8-1 (4 · ε_SiO2 ≈ 4 · 8.8e-12 F/m).
pub const EPS_FACTOR: f64 = 4.0 * 8.8e-12;
/// Copper resistivity factor of Eq 8-1 (17e-9 Ω·m).
pub const RHO_CU: f64 = 17e-9;

/// Routing-layer RC delay in seconds (Eq 8-1).
pub fn routing_delay(l: f64, d: f64, t: f64) -> f64 {
    (EPS_FACTOR * l * l / d) * (RHO_CU / t)
}

/// Largest routing-layer span (meters) achieving `clock_hz` with a
/// half-period timing budget — the paper's "overall delay less than
/// 0.5e-9 sec" at 1 GHz.
pub fn max_span_for_clock(clock_hz: f64, d: f64, t: f64) -> f64 {
    let budget = 0.5 / clock_hz;
    (budget * d * t / (EPS_FACTOR * RHO_CU)).sqrt()
}

/// Chip-area estimate for a content movable memory of `bytes` capacity at
/// `um2_per_32bit_pe` µm² per 32-bit PE (the paper uses ~2 µm² with its
/// 2-gate/bit + 4-gate/PE overhead at then-current density).
pub fn chip_area_mm2(bytes: u64, um2_per_32bit_pe: f64) -> f64 {
    let pes = bytes as f64 / 4.0; // 32-bit PEs
    pes * um2_per_32bit_pe / 1e6
}

/// PE count reachable by one routing layer of span `l_m` at `um2` per PE.
pub fn pes_per_layer(l_m: f64, um2_per_pe: f64) -> f64 {
    let area_um2 = (l_m * 1e6) * (l_m * 1e6);
    area_um2 / um2_per_pe
}

/// The cache-depth trade (§8): with an output cache of depth `depth` and a
/// `bus_hz` system bus, each routing layer only needs `bus_hz / depth`.
pub fn routing_clock_with_cache(bus_hz: f64, depth: u32) -> f64 {
    bus_hz / depth as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq_8_1_prefactor_matches_paper() {
        // 0.6e-18 · L²/D/T (the paper's collapsed constant).
        let (l, d, t) = (1e-3, 25e-9, 10e-9);
        let direct = routing_delay(l, d, t);
        let collapsed = 0.6e-18 * l * l / d / t;
        let rel = (direct - collapsed).abs() / collapsed;
        assert!(rel < 0.01, "prefactor drift {rel}");
    }

    #[test]
    fn spans_match_the_papers_scenarios() {
        // Eq 8-1 at D=25nm, T=10nm: ~0.46 mm at 1 GHz; the paper's
        // "1.5x1.5 mm²" figure is its 100 MHz cache-depth-4 scenario
        // (0.46·√10 ≈ 1.45 mm) — both reproduced here.
        let l_1ghz = max_span_for_clock(1e9, 25e-9, 10e-9);
        assert!(
            (0.4e-3..0.52e-3).contains(&l_1ghz),
            "1 GHz span {l_1ghz} m (expected ~0.46 mm)"
        );
        let l_100mhz = max_span_for_clock(100e6, 25e-9, 10e-9);
        assert!(
            (1.2e-3..1.8e-3).contains(&l_100mhz),
            "100 MHz span {l_100mhz} m vs the paper's ~1.5 mm"
        );
        // The delay at the span meets the half-period budget.
        assert!(routing_delay(l_1ghz, 25e-9, 10e-9) <= 0.5e-9 * 1.001);
    }

    #[test]
    fn four_gbit_chip_is_about_15x15_mm() {
        // Paper: ~2 µm² per 32-bit PE -> "4G-byte ... about 15x15 mm²".
        // By the paper's own numbers, 2 µm² × 1e9 PEs is ~2000 mm²; the
        // 15×15 mm² figure matches a 4 G*bit* device (2 µm² × 134e6 PEs ≈
        // 268 mm²) — we reproduce the latter and note the discrepancy in
        // EXPERIMENTS.md E16.
        let area_4gbit = chip_area_mm2((4u64 << 30) / 8, 2.0);
        assert!(
            (150.0..400.0).contains(&area_4gbit),
            "area {area_4gbit} mm² vs paper's ~225 mm²"
        );
        let area_4gbyte = chip_area_mm2(4u64 << 30, 2.0);
        assert!(area_4gbyte > 1500.0, "4 GByte at 2 µm²/PE is ~2000 mm²");
    }

    #[test]
    fn cache_depth_4_slows_routing_to_100mhz() {
        // Paper: cache depth 4, 400 MHz system bus -> 100 MHz routing,
        // which relaxes the span to the paper's 1.5x1.5 mm².
        let clk = routing_clock_with_cache(400e6, 4);
        assert_eq!(clk, 100e6);
        let l = max_span_for_clock(clk, 25e-9, 10e-9);
        assert!(
            l > 1.2e-3,
            "100 MHz should allow ~1.5 mm spans, got {l}"
        );
    }

    #[test]
    fn delay_scales_quadratically_with_span() {
        let d1 = routing_delay(1e-3, 25e-9, 10e-9);
        let d2 = routing_delay(2e-3, 25e-9, 10e-9);
        assert!((d2 / d1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn thicker_layers_are_faster() {
        let thin = routing_delay(1e-3, 25e-9, 10e-9);
        let thick = routing_delay(1e-3, 50e-9, 20e-9);
        assert!(thick < thin / 3.9);
    }
}
