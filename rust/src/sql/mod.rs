//! Mini SQL engine on a content comparable memory (§6.2).
//!
//! "A content comparable memory compares a field of all array items with
//! one value concurrently in ~1 instruction cycles without any
//! preprocessing ... thus it can be used to implement SQL with vastly
//! improved speed."
//!
//! Fixed-width rows (Rule 4's equal-size array items) with big-endian
//! unsigned columns; predicates run as concurrent field compares, combined
//! with the Fig 7 neighboring-bit mechanism; results are read through the
//! match lines. The serial comparators (full scan, and the B-tree-style
//! [`crate::baseline::SortedIndex`]) are the E4/E17 baselines.

use std::collections::BTreeMap;

use crate::device::comparable::{
    CmpCode, Combine, ContentComparableMemory, FieldSpec,
};
use crate::error::{CpmError, Result};

/// A column: name + fixed byte width (1..=8, big-endian unsigned).
#[derive(Debug, Clone)]
pub struct Column {
    /// Column name.
    pub name: String,
    /// Width in bytes.
    pub width: usize,
}

/// A table schema.
#[derive(Debug, Clone, Default)]
pub struct Schema {
    /// Columns in storage order.
    pub columns: Vec<Column>,
}

impl Schema {
    /// Build a schema from `(name, width)` pairs.
    pub fn new(cols: &[(&str, usize)]) -> Result<Self> {
        for &(name, w) in cols {
            if w == 0 || w > 8 {
                return Err(CpmError::Sql(format!("column {name}: width {w} not in 1..=8")));
            }
        }
        Ok(Schema {
            columns: cols
                .iter()
                .map(|&(n, w)| Column {
                    name: n.to_string(),
                    width: w,
                })
                .collect(),
        })
    }

    /// Row size in bytes (the Rule 4 carry number).
    pub fn row_size(&self) -> usize {
        self.columns.iter().map(|c| c.width).sum()
    }

    /// Field spec of a column by name.
    pub fn field(&self, name: &str) -> Result<FieldSpec> {
        let mut offset = 0;
        for c in &self.columns {
            if c.name == name {
                return Ok(FieldSpec {
                    offset,
                    len: c.width,
                });
            }
            offset += c.width;
        }
        Err(CpmError::Sql(format!("unknown column {name}")))
    }

    /// Encode a row of u64 values (must match the column count).
    pub fn encode_row(&self, values: &[u64]) -> Result<Vec<u8>> {
        if values.len() != self.columns.len() {
            return Err(CpmError::Sql(format!(
                "row arity {} != {}",
                values.len(),
                self.columns.len()
            )));
        }
        let mut out = Vec::with_capacity(self.row_size());
        for (c, &v) in self.columns.iter().zip(values) {
            let max = if c.width == 8 { u64::MAX } else { (1u64 << (8 * c.width)) - 1 };
            if v > max {
                return Err(CpmError::Sql(format!(
                    "value {v} overflows column {} ({} bytes)",
                    c.name, c.width
                )));
            }
            out.extend_from_slice(&v.to_be_bytes()[8 - c.width..]);
        }
        Ok(out)
    }
}

/// Predicate operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredOp {
    /// Equal.
    Eq,
    /// Not equal.
    Ne,
    /// Less than.
    Lt,
    /// Less or equal.
    Le,
    /// Greater than.
    Gt,
    /// Greater or equal.
    Ge,
}

impl PredOp {
    fn cmp_code(self) -> CmpCode {
        match self {
            PredOp::Eq => CmpCode::Eq,
            PredOp::Ne => CmpCode::Ne,
            PredOp::Lt => CmpCode::Lt,
            PredOp::Le => CmpCode::Le,
            PredOp::Gt => CmpCode::Gt,
            PredOp::Ge => CmpCode::Ge,
        }
    }

    /// Evaluate on u64 (reference/baseline semantics).
    pub fn eval(self, a: u64, b: u64) -> bool {
        match self {
            PredOp::Eq => a == b,
            PredOp::Ne => a != b,
            PredOp::Lt => a < b,
            PredOp::Le => a <= b,
            PredOp::Gt => a > b,
            PredOp::Ge => a >= b,
        }
    }
}

/// One predicate: `column op value`.
#[derive(Debug, Clone)]
pub struct Predicate {
    /// Column name.
    pub column: String,
    /// Operator.
    pub op: PredOp,
    /// Comparison value.
    pub value: u64,
}

/// A conjunctive/disjunctive query.
#[derive(Debug, Clone)]
pub struct Query {
    /// Predicates (all AND-ed or all OR-ed).
    pub predicates: Vec<Predicate>,
    /// `true` = AND, `false` = OR.
    pub conjunctive: bool,
    /// `true` = return only the count.
    pub count_only: bool,
}

impl Query {
    /// Parse a tiny SQL-ish string:
    /// `SELECT [COUNT|ROWS] WHERE col op val [AND|OR col op val]*`
    pub fn parse(text: &str) -> Result<Query> {
        let tokens: Vec<&str> = text.split_whitespace().collect();
        let mut i = 0;
        let expect = |i: &mut usize, what: &str, tokens: &[&str]| -> Result<()> {
            if tokens.get(*i).map(|t| t.eq_ignore_ascii_case(what)) == Some(true) {
                *i += 1;
                Ok(())
            } else {
                Err(CpmError::Sql(format!(
                    "expected {what} at token {} in {text:?}",
                    *i
                )))
            }
        };
        expect(&mut i, "select", &tokens)?;
        let count_only = match tokens.get(i).map(|t| t.to_ascii_lowercase()) {
            Some(t) if t == "count" => {
                i += 1;
                true
            }
            Some(t) if t == "rows" => {
                i += 1;
                false
            }
            _ => false,
        };
        expect(&mut i, "where", &tokens)?;
        let mut predicates = Vec::new();
        let mut conjunctive = true;
        loop {
            let column = tokens
                .get(i)
                .ok_or_else(|| CpmError::Sql("missing column".into()))?
                .to_string();
            let op = match tokens.get(i + 1).copied() {
                Some("=") | Some("==") => PredOp::Eq,
                Some("!=") | Some("<>") => PredOp::Ne,
                Some("<") => PredOp::Lt,
                Some("<=") => PredOp::Le,
                Some(">") => PredOp::Gt,
                Some(">=") => PredOp::Ge,
                other => {
                    return Err(CpmError::Sql(format!("bad operator {other:?}")));
                }
            };
            let value: u64 = tokens
                .get(i + 2)
                .and_then(|t| t.parse().ok())
                .ok_or_else(|| CpmError::Sql("bad value".into()))?;
            predicates.push(Predicate { column, op, value });
            i += 3;
            match tokens.get(i).map(|t| t.to_ascii_lowercase()) {
                Some(t) if t == "and" => {
                    conjunctive = true;
                    i += 1;
                }
                Some(t) if t == "or" => {
                    conjunctive = false;
                    i += 1;
                }
                None => break,
                Some(t) => {
                    return Err(CpmError::Sql(format!("unexpected token {t}")));
                }
            }
        }
        Ok(Query {
            predicates,
            conjunctive,
            count_only,
        })
    }
}

/// Query result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueryResult {
    /// Row indices (ascending).
    Rows(Vec<usize>),
    /// Match count only.
    Count(usize),
}

/// Device-pass accounting for one batched query group (E20).
///
/// Counts predicate compare passes: a query that repeats an
/// already-answered query shares *all* of its compare passes with the
/// first occurrence. Queries that error contribute to neither counter.
#[derive(Debug, Default, Clone, Copy)]
pub struct SqlBatchStats {
    /// Predicate occurrences across all answered queries in the batch.
    pub total_predicates: u64,
    /// Compare passes actually run on the device.
    pub distinct_predicates: u64,
}

impl SqlBatchStats {
    /// Compare passes avoided by sharing (the batch-amortization gain).
    pub fn shared_passes(&self) -> u64 {
        self.total_predicates - self.distinct_predicates
    }
}

/// Memo key for a whole query: predicates in order plus the combination
/// and result shape (two queries with the same key are interchangeable
/// against an immutable table).
fn query_key(q: &Query) -> String {
    let mut s = String::new();
    for p in &q.predicates {
        s.push_str(&format!("{}\x01{}\x01{}\x02", p.column, p.op as u8, p.value));
    }
    s.push(if q.conjunctive { '&' } else { '|' });
    s.push(if q.count_only { '#' } else { '*' });
    s
}

/// Fold one predicate's verdict bitset into the running combination.
fn fold_bits(acc: Option<Vec<bool>>, bits: &[bool], conjunctive: bool) -> Vec<bool> {
    match acc {
        None => bits.to_vec(),
        Some(prev) => prev
            .iter()
            .zip(bits.iter())
            .map(|(&a, &b)| if conjunctive { a && b } else { a || b })
            .collect(),
    }
}

/// Turn a combined verdict bitset into the requested result shape.
fn materialize(bits: &[bool], count_only: bool) -> QueryResult {
    let rows: Vec<usize> = bits
        .iter()
        .enumerate()
        .filter_map(|(i, &b)| if b { Some(i) } else { None })
        .collect();
    if count_only {
        QueryResult::Count(rows.len())
    } else {
        QueryResult::Rows(rows)
    }
}

/// A table resident in a content comparable memory.
#[derive(Debug)]
pub struct Table {
    /// Schema.
    pub schema: Schema,
    mem: ContentComparableMemory,
    n_rows: usize,
    /// Row values kept host-side for verification/baselines only
    /// (never consulted by the CPM query path).
    shadow: Vec<Vec<u64>>,
}

impl Table {
    /// Create a table with capacity for `max_rows`.
    pub fn new(schema: Schema, max_rows: usize) -> Self {
        let size = (schema.row_size() * max_rows).max(1);
        Table {
            schema,
            mem: ContentComparableMemory::new(size),
            n_rows: 0,
            shadow: Vec::new(),
        }
    }

    /// Insert a row (exclusive-bus streaming; counted by the device).
    pub fn insert(&mut self, values: &[u64]) -> Result<usize> {
        let row = self.schema.encode_row(values)?;
        let addr = self.n_rows * self.schema.row_size();
        if addr + row.len() > self.mem.len() {
            return Err(CpmError::Sql("table full".into()));
        }
        self.mem.load(addr, &row);
        self.shadow.push(values.to_vec());
        self.n_rows += 1;
        Ok(self.n_rows - 1)
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.n_rows
    }

    /// True if the table has no rows.
    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    /// Execute a query on the device. Cost accrues on the device counters.
    pub fn query(&mut self, q: &Query) -> Result<QueryResult> {
        if q.predicates.is_empty() {
            return Err(CpmError::Sql("empty predicate list".into()));
        }
        let item = self.schema.row_size();
        let n = self.n_rows;
        // The combined verdict accumulates at a scratch lattice (Fig 7's
        // neighboring-bit combination). compare_field clears its own
        // field's lattices, so the scratch byte must avoid every byte a
        // *later* predicate will compare.
        let mut used_later = vec![false; item];
        for p in &q.predicates[1..] {
            let f = self.schema.field(&p.column)?;
            for b in f.offset..f.offset + f.len {
                used_later[b] = true;
            }
        }
        let scratch = (0..item).find(|&b| !used_later[b]);
        if let Some(scratch) = scratch {
            for (k, p) in q.predicates.iter().enumerate() {
                let field = self.schema.field(&p.column)?;
                let col = self
                    .schema
                    .columns
                    .iter()
                    .find(|c| c.name == p.column)
                    .ok_or_else(|| CpmError::Sql(format!("unknown column {}", p.column)))?;
                let value = self.schema_value_bytes(col, p.value)?;
                self.mem
                    .compare_field(0, item, n, field, p.op.cmp_code(), &value);
                if k == 0 {
                    if field.offset != scratch {
                        self.mem.save_verdict(0, item, n, field.offset, scratch);
                    }
                } else {
                    self.mem.combine(
                        0,
                        item,
                        n,
                        scratch,
                        field.offset,
                        if q.conjunctive { Combine::And } else { Combine::Or },
                    );
                }
            }
            let spec = FieldSpec {
                offset: scratch,
                len: 1,
            };
            return if q.count_only {
                Ok(QueryResult::Count(self.mem.selected_count(0, item, n, spec)))
            } else {
                Ok(QueryResult::Rows(self.mem.selected_items(0, item, n, spec)))
            };
        }
        // Pathological case (predicates cover every row byte): combine
        // per-predicate match-line readouts host-side.
        let mut acc: Option<Vec<bool>> = None;
        for p in &q.predicates {
            let bits = self.predicate_bits(p)?;
            acc = Some(fold_bits(acc, &bits, q.conjunctive));
        }
        Ok(materialize(&acc.unwrap(), q.count_only))
    }

    /// Run one predicate's concurrent field compare and read the match
    /// lines back as a per-row verdict bitset.
    fn predicate_bits(&mut self, p: &Predicate) -> Result<Vec<bool>> {
        let item = self.schema.row_size();
        let n = self.n_rows;
        let field = self.schema.field(&p.column)?;
        let col = self
            .schema
            .columns
            .iter()
            .find(|c| c.name == p.column)
            .ok_or_else(|| CpmError::Sql(format!("unknown column {}", p.column)))?;
        let value = self.schema_value_bytes(col, p.value)?;
        self.mem
            .compare_field(0, item, n, field, p.op.cmp_code(), &value);
        let hits = self.mem.selected_items(0, item, n, field);
        let mut bits = vec![false; n];
        for h in hits {
            bits[h] = true;
        }
        Ok(bits)
    }

    /// Execute a batch of queries with *shared field-compare passes*:
    /// the table is immutable within a batch, so a query whose
    /// predicate list repeats an earlier query's is answered from a memo
    /// at **zero device cost** — the hot-query-template case
    /// (MASIM/SIMDRAM-style per-batch control amortization). Memo
    /// misses run [`Table::query`]'s device combine path unchanged, so
    /// a batch of distinct queries costs exactly what serial serving
    /// costs and `COUNT` queries keep their ~1-cycle parallel-counter
    /// readout. Results are identical to running [`Table::query`] per
    /// query. (Sharing is per whole query, not per predicate: sharing a
    /// single predicate across different queries would force its
    /// match-line readout host-side at one exclusive op per matching
    /// row, which costs more than the compare ladder it saves — see
    /// DESIGN.md "Pool batching & eviction".)
    pub fn query_batch(
        &mut self,
        queries: &[Query],
    ) -> (Vec<Result<QueryResult>>, SqlBatchStats) {
        let mut stats = SqlBatchStats::default();
        let mut memo: BTreeMap<String, QueryResult> = BTreeMap::new();
        let out: Vec<Result<QueryResult>> = queries
            .iter()
            .map(|q| {
                let key = query_key(q);
                if let Some(r) = memo.get(&key) {
                    stats.total_predicates += q.predicates.len() as u64;
                    return Ok(r.clone());
                }
                let r = self.query(q)?;
                stats.total_predicates += q.predicates.len() as u64;
                stats.distinct_predicates += q.predicates.len() as u64;
                memo.insert(key, r.clone());
                Ok(r)
            })
            .collect();
        (out, stats)
    }

    fn schema_value_bytes(&self, col: &Column, v: u64) -> Result<Vec<u8>> {
        let max = if col.width == 8 { u64::MAX } else { (1u64 << (8 * col.width)) - 1 };
        // Clamp out-of-range probe values to the column domain (a probe
        // larger than the domain compares like the domain maximum).
        let v = v.min(max);
        Ok(v.to_be_bytes()[8 - col.width..].to_vec())
    }

    /// Reference (host-side) evaluation for verification and baselines.
    pub fn query_reference(&self, q: &Query) -> QueryResult {
        let hits: Vec<usize> = self
            .shadow
            .iter()
            .enumerate()
            .filter(|(_, row)| {
                let verdicts = q.predicates.iter().map(|p| {
                    let idx = self
                        .schema
                        .columns
                        .iter()
                        .position(|c| c.name == p.column)
                        .expect("column");
                    let col = &self.schema.columns[idx];
                    let max = if col.width == 8 {
                        u64::MAX
                    } else {
                        (1u64 << (8 * col.width)) - 1
                    };
                    p.op.eval(row[idx], p.value.min(max))
                });
                if q.conjunctive {
                    verdicts.fold(true, |a, b| a && b)
                } else {
                    verdicts.fold(false, |a, b| a || b)
                }
            })
            .map(|(i, _)| i)
            .collect();
        if q.count_only {
            QueryResult::Count(hits.len())
        } else {
            QueryResult::Rows(hits)
        }
    }

    /// Shadow row values (baseline input).
    pub fn column_values(&self, name: &str) -> Result<Vec<u64>> {
        let idx = self
            .schema
            .columns
            .iter()
            .position(|c| c.name == name)
            .ok_or_else(|| CpmError::Sql(format!("unknown column {name}")))?;
        Ok(self.shadow.iter().map(|r| r[idx]).collect())
    }

    /// Device cost counters.
    pub fn device_cost(&self) -> crate::cycles::ConcurrentCost {
        self.mem.cost()
    }

    /// Reset device cost counters.
    pub fn reset_device_cost(&mut self) {
        self.mem.reset_cost();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn orders_table(n: usize, seed: u64) -> Table {
        let schema = Schema::new(&[("price", 2), ("qty", 1), ("region", 1)]).unwrap();
        let mut t = Table::new(schema, n);
        let mut rng = Rng::new(seed);
        for _ in 0..n {
            t.insert(&[
                rng.below(10_000),
                rng.below(100),
                rng.below(8),
            ])
            .unwrap();
        }
        t
    }

    #[test]
    fn schema_layout() {
        let s = Schema::new(&[("a", 2), ("b", 4), ("c", 1)]).unwrap();
        assert_eq!(s.row_size(), 7);
        assert_eq!(s.field("b").unwrap().offset, 2);
        assert_eq!(s.field("c").unwrap().offset, 6);
        assert!(s.field("zz").is_err());
        assert!(Schema::new(&[("x", 0)]).is_err());
        assert!(Schema::new(&[("x", 9)]).is_err());
    }

    #[test]
    fn encode_row_bounds() {
        let s = Schema::new(&[("a", 1)]).unwrap();
        assert_eq!(s.encode_row(&[255]).unwrap(), vec![255]);
        assert!(s.encode_row(&[256]).is_err());
        assert!(s.encode_row(&[1, 2]).is_err());
    }

    #[test]
    fn single_predicate_queries_match_reference() {
        let mut t = orders_table(500, 7);
        for (op, v) in [
            (PredOp::Lt, 5000u64),
            (PredOp::Ge, 9000),
            (PredOp::Eq, t.shadow[42][0]),
            (PredOp::Ne, 0),
            (PredOp::Le, 100),
            (PredOp::Gt, 9999),
        ] {
            let q = Query {
                predicates: vec![Predicate {
                    column: "price".into(),
                    op,
                    value: v,
                }],
                conjunctive: true,
                count_only: false,
            };
            assert_eq!(t.query(&q).unwrap(), t.query_reference(&q), "{op:?} {v}");
        }
    }

    #[test]
    fn conjunctive_and_disjunctive_queries() {
        let mut t = orders_table(300, 8);
        let q = Query::parse("SELECT ROWS WHERE price < 5000 AND qty >= 50").unwrap();
        assert_eq!(t.query(&q).unwrap(), t.query_reference(&q));
        let q = Query::parse("SELECT ROWS WHERE price < 100 OR region = 3").unwrap();
        assert_eq!(t.query(&q).unwrap(), t.query_reference(&q));
        let q = Query::parse("SELECT COUNT WHERE qty < 10 AND region != 0").unwrap();
        assert_eq!(t.query(&q).unwrap(), t.query_reference(&q));
    }

    #[test]
    fn parser_accepts_and_rejects() {
        assert!(Query::parse("SELECT COUNT WHERE a = 1").is_ok());
        assert!(Query::parse("select rows where a >= 2 or b < 3").is_ok());
        assert!(Query::parse("WHERE a = 1").is_err());
        assert!(Query::parse("SELECT WHERE a ~ 1").is_err());
        assert!(Query::parse("SELECT WHERE a = x").is_err());
        let q = Query::parse("SELECT COUNT WHERE a = 1 AND b > 2").unwrap();
        assert!(q.count_only && q.conjunctive);
        assert_eq!(q.predicates.len(), 2);
    }

    #[test]
    fn query_cost_independent_of_row_count() {
        let mut small = orders_table(32, 9);
        let mut large = orders_table(4096, 10);
        let q = Query::parse("SELECT COUNT WHERE price < 1234").unwrap();
        small.reset_device_cost();
        small.query(&q).unwrap();
        let c_small = small.device_cost().macro_cycles;
        large.reset_device_cost();
        large.query(&q).unwrap();
        let c_large = large.device_cost().macro_cycles;
        assert_eq!(c_small, c_large, "CPM query cost must not scale with N");
        assert!(c_small <= 12, "2-byte compare ladder + readout: {c_small}");
    }

    #[test]
    fn duplicate_column_range_query() {
        // Both predicates on the same column: the scratch lattice must
        // dodge the re-cleared field bytes.
        let mut t = orders_table(400, 12);
        let q = Query::parse("SELECT ROWS WHERE price >= 1000 AND price < 3000").unwrap();
        assert_eq!(t.query(&q).unwrap(), t.query_reference(&q));
    }

    #[test]
    fn all_bytes_covered_falls_back_host_side() {
        // Single-column schema, two predicates on it: every row byte is a
        // future compare target -> host-side combination path.
        let schema = Schema::new(&[("v", 2)]).unwrap();
        let mut t = Table::new(schema, 100);
        let mut rng = Rng::new(13);
        for _ in 0..100 {
            t.insert(&[rng.below(1000)]).unwrap();
        }
        let q = Query::parse("SELECT ROWS WHERE v >= 100 AND v < 900").unwrap();
        assert_eq!(t.query(&q).unwrap(), t.query_reference(&q));
    }

    #[test]
    fn batched_queries_match_serial_and_share_passes() {
        let mut t = orders_table(400, 14);
        let texts = [
            "SELECT COUNT WHERE price < 5000",
            "SELECT ROWS WHERE price < 5000 AND qty >= 50",
            "SELECT COUNT WHERE price < 5000", // duplicate template
            "SELECT ROWS WHERE qty >= 50 OR region = 2",
            "SELECT COUNT WHERE price < 5000 AND region = 2",
        ];
        let queries: Vec<Query> = texts.iter().map(|s| Query::parse(s).unwrap()).collect();
        let serial: Vec<QueryResult> = queries.iter().map(|q| t.query_reference(q)).collect();
        t.reset_device_cost();
        let (batched, stats) = t.query_batch(&queries);
        for (b, s) in batched.iter().zip(&serial) {
            assert_eq!(b.as_ref().unwrap(), s);
        }
        // 8 predicate occurrences; the duplicate COUNT template shares
        // its 1 compare pass, the 4 distinct queries run 7.
        assert_eq!(stats.total_predicates, 8);
        assert_eq!(stats.distinct_predicates, 7);
        assert_eq!(stats.shared_passes(), 1);
        // Batched macro cost beats running every query on the device.
        let batched_cycles = t.device_cost().macro_cycles;
        t.reset_device_cost();
        for q in &queries {
            t.query(q).unwrap();
        }
        let serial_cycles = t.device_cost().macro_cycles;
        assert!(
            batched_cycles < serial_cycles,
            "batched {batched_cycles} vs serial {serial_cycles}"
        );
    }

    #[test]
    fn batched_errors_stay_per_query() {
        let mut t = orders_table(50, 15);
        let good = Query::parse("SELECT COUNT WHERE price < 100").unwrap();
        let bad = Query::parse("SELECT COUNT WHERE nosuch = 1").unwrap();
        let empty = Query {
            predicates: Vec::new(),
            conjunctive: true,
            count_only: true,
        };
        let (results, _) = t.query_batch(&[good.clone(), bad, empty]);
        assert_eq!(results[0].as_ref().unwrap(), &t.query_reference(&good));
        assert!(results[1].is_err());
        assert!(results[2].is_err());
    }

    #[test]
    fn three_predicate_combination() {
        let mut t = orders_table(200, 11);
        let q = Query::parse("SELECT ROWS WHERE price >= 1000 AND qty > 20 AND region <= 4")
            .unwrap();
        assert_eq!(t.query(&q).unwrap(), t.query_reference(&q));
    }
}
