//! Serial sorting baselines (§7.7's comparison targets): quicksort for
//! random arrays (O(N log N)) and insertion sort for nearly-sorted arrays
//! (O(N + inversions)) — both with per-touch bus accounting.

use super::SerialMachine;

/// Quicksort with cost accounting (Hoare partition, middle pivot).
pub fn quicksort(m: &mut SerialMachine, data: &mut [i32]) {
    fn go(m: &mut SerialMachine, data: &mut [i32], lo: isize, hi: isize) {
        if lo >= hi {
            return;
        }
        let pivot = data[((lo + hi) / 2) as usize];
        m.touch(1);
        let (mut i, mut j) = (lo - 1, hi + 1);
        loop {
            loop {
                i += 1;
                m.touch(1);
                m.compute(1);
                if data[i as usize] >= pivot {
                    break;
                }
            }
            loop {
                j -= 1;
                m.touch(1);
                m.compute(1);
                if data[j as usize] <= pivot {
                    break;
                }
            }
            if i >= j {
                break;
            }
            data.swap(i as usize, j as usize);
            m.touch(4); // two reads + two writes
        }
        go(m, data, lo, j);
        go(m, data, j + 1, hi);
    }
    let hi = data.len() as isize - 1;
    go(m, data, 0, hi);
}

/// Insertion sort — the serial best case for nearly-sorted input.
pub fn insertion_sort(m: &mut SerialMachine, data: &mut [i32]) {
    for i in 1..data.len() {
        let v = data[i];
        m.touch(1);
        let mut j = i;
        while j > 0 {
            m.touch(1);
            m.compute(1);
            if data[j - 1] <= v {
                break;
            }
            data[j] = data[j - 1];
            m.touch(1);
            j -= 1;
        }
        data[j] = v;
        m.touch(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quicksort_sorts() {
        let mut rng = Rng::new(111);
        for n in [0usize, 1, 2, 100, 1000] {
            let mut data = rng.vec_i32(n, -1000, 1000);
            let mut want = data.clone();
            want.sort_unstable();
            let mut m = SerialMachine::new();
            quicksort(&mut m, &mut data);
            assert_eq!(data, want, "n={n}");
        }
    }

    #[test]
    fn insertion_sorts_and_is_cheap_when_nearly_sorted() {
        let n = 2000;
        let mut nearly: Vec<i32> = (0..n).collect();
        nearly.swap(100, 101);
        nearly.swap(1500, 1501);
        let mut m_nearly = SerialMachine::new();
        insertion_sort(&mut m_nearly, &mut nearly);
        assert!(nearly.windows(2).all(|w| w[0] <= w[1]));

        let mut rng = Rng::new(112);
        let mut random = rng.vec_i32(n as usize, -1000, 1000);
        let mut m_random = SerialMachine::new();
        insertion_sort(&mut m_random, &mut random);
        assert!(random.windows(2).all(|w| w[0] <= w[1]));
        // Nearly-sorted ~N; random ~N²/4.
        assert!(m_random.cost.cpu_cycles > 20 * m_nearly.cost.cpu_cycles);
    }

    #[test]
    fn quicksort_cost_is_n_log_n_ish() {
        let mut rng = Rng::new(113);
        let mut small = rng.vec_i32(1024, -10_000, 10_000);
        let mut big = rng.vec_i32(8192, -10_000, 10_000);
        let mut m1 = SerialMachine::new();
        quicksort(&mut m1, &mut small);
        let mut m2 = SerialMachine::new();
        quicksort(&mut m2, &mut big);
        let ratio = m2.cost.cpu_cycles as f64 / m1.cost.cpu_cycles as f64;
        // 8x data, ~10.4x ideal for N log N; allow slack.
        assert!(ratio > 6.0 && ratio < 20.0, "ratio={ratio}");
    }
}
