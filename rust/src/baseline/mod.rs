//! The serial bus-sharing baseline (§2's "current most common CPU/memory
//! bus-sharing architecture").
//!
//! Every CPM claim in the paper is a comparison against this machine: a
//! serial CPU that must stream each word it touches over the shared system
//! bus. [`SerialMachine`] counts `cpu_cycles` (one simple op each) and
//! `bus_words` (processing-purpose traffic — the §2 bottleneck), and the
//! submodules implement the serial counterpart of every CPM operation:
//! memmove insertion/deletion, linear scan and B-tree-indexed comparison,
//! naive and KMP substring search, convolution, reduction, quicksort and
//! insertion sort, template scan, and per-pixel line detection.

pub mod index;
pub mod search;
pub mod sort;
pub mod stencil;

pub use index::SortedIndex;

use crate::cycles::SerialCost;

/// The serial CPU + RAM model. All operations tally cost on `self.cost`.
#[derive(Debug, Default, Clone)]
pub struct SerialMachine {
    /// Accumulated cost.
    pub cost: SerialCost,
}

impl SerialMachine {
    /// Fresh machine.
    pub fn new() -> Self {
        SerialMachine::default()
    }

    /// Reset counters.
    pub fn reset(&mut self) {
        self.cost = SerialCost::default();
    }

    /// Charge `n` ops that each touch memory through the bus.
    #[inline]
    pub fn touch(&mut self, n: u64) {
        self.cost += SerialCost::touching(n);
    }

    /// Charge `n` register-only ops.
    #[inline]
    pub fn compute(&mut self, n: u64) {
        self.cost += SerialCost::compute(n);
    }

    // ---- §4 memory management ------------------------------------------

    /// Insert `insert_len` bytes at `addr` into a used region of `used`
    /// bytes: the classic memmove — every byte after `addr` crosses the
    /// bus twice (read + write).
    pub fn insert_memmove(&mut self, addr: usize, insert_len: usize, used: usize) {
        let moved = used.saturating_sub(addr) as u64;
        self.touch(2 * moved + insert_len as u64);
    }

    /// Delete `del_len` bytes at `addr` (memmove the tail down).
    pub fn delete_memmove(&mut self, addr: usize, del_len: usize, used: usize) {
        let moved = used.saturating_sub(addr + del_len) as u64;
        self.touch(2 * moved);
    }

    // ---- §6 comparison --------------------------------------------------

    /// Compare one field of every item against a value by scanning the
    /// table: N reads + N compares.
    pub fn scan_compare<T: Copy, F: Fn(T) -> bool>(
        &mut self,
        items: &[T],
        pred: F,
    ) -> Vec<usize> {
        self.touch(items.len() as u64);
        self.compute(items.len() as u64);
        items
            .iter()
            .enumerate()
            .filter_map(|(i, &v)| if pred(v) { Some(i) } else { None })
            .collect()
    }

    /// Sum an array serially.
    pub fn sum(&mut self, items: &[i32]) -> i64 {
        self.touch(items.len() as u64);
        self.compute(items.len() as u64);
        items.iter().map(|&v| v as i64).sum()
    }

    /// Maximum of an array serially.
    pub fn max(&mut self, items: &[i32]) -> Option<i32> {
        self.touch(items.len() as u64);
        self.compute(items.len() as u64);
        items.iter().copied().max()
    }

    /// Histogram by scanning: one pass, one bucket update per item.
    pub fn histogram(&mut self, items: &[i32], bounds: &[i32]) -> Vec<usize> {
        self.touch(items.len() as u64);
        // binary search per item over the bounds
        self.compute(items.len() as u64 * ((bounds.len() as u64).max(2)).ilog2() as u64);
        let mut counts = vec![0usize; bounds.len() + 1];
        for &v in items {
            let k = bounds.iter().filter(|&&b| v >= b).count();
            counts[k] += 1;
        }
        counts
    }

    // ---- §7.8 -----------------------------------------------------------

    /// Threshold by scanning.
    pub fn threshold(&mut self, items: &[i32], t: i32) -> usize {
        self.touch(items.len() as u64);
        self.compute(items.len() as u64);
        items.iter().filter(|&&v| v > t).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memmove_costs_scale_with_tail() {
        let mut m = SerialMachine::new();
        m.insert_memmove(10, 4, 1000);
        assert_eq!(m.cost.bus_words, 2 * 990 + 4);
        m.reset();
        m.delete_memmove(10, 4, 1000);
        assert_eq!(m.cost.bus_words, 2 * 986);
    }

    #[test]
    fn scan_compare_touches_every_item() {
        let mut m = SerialMachine::new();
        let items: Vec<i32> = (0..100).collect();
        let hits = m.scan_compare(&items, |v| v >= 90);
        assert_eq!(hits.len(), 10);
        assert_eq!(m.cost.bus_words, 100);
        assert_eq!(m.cost.cpu_cycles, 200);
    }

    #[test]
    fn reductions_and_threshold() {
        let mut m = SerialMachine::new();
        assert_eq!(m.sum(&[1, 2, 3]), 6);
        assert_eq!(m.max(&[5, -2, 9]), Some(9));
        assert_eq!(m.threshold(&[1, 5, 10], 4), 2);
        assert!(m.cost.bus_words >= 9);
    }

    #[test]
    fn histogram_matches_cpm_semantics() {
        let mut m = SerialMachine::new();
        let items = [1, 25, 50, 75, 99];
        let counts = m.histogram(&items, &[25, 50, 75]);
        assert_eq!(counts, vec![1, 1, 1, 2]);
    }
}
