//! Serial image/array baselines: convolution, template scan (the ~N·M and
//! ~Nx·Ny·Mx·My costs of §7.6), and per-pixel line detection (~N·D²).

use super::SerialMachine;

/// 1-D convolution with an odd-length kernel, zero boundary.
pub fn convolve_1d(m: &mut SerialMachine, values: &[i32], kernel: &[i64]) -> Vec<i64> {
    let half = (kernel.len() / 2) as i64;
    let n = values.len() as i64;
    let mut out = vec![0i64; values.len()];
    for i in 0..n {
        for (k, &c) in kernel.iter().enumerate() {
            let j = i + k as i64 - half;
            m.compute(1);
            if j >= 0 && j < n {
                m.touch(1);
                out[i as usize] += c * values[j as usize] as i64;
            }
        }
        m.touch(1); // store
    }
    out
}

/// Serial 1-D SAD template scan — O(N·M).
pub fn template_scan_1d(m: &mut SerialMachine, values: &[i32], template: &[i32]) -> Vec<i64> {
    let n = values.len();
    let tm = template.len();
    let mut out = Vec::with_capacity(n - tm + 1);
    for p in 0..=n - tm {
        let mut s = 0i64;
        for (k, &t) in template.iter().enumerate() {
            m.touch(1);
            m.compute(1);
            s += (values[p + k] as i64 - t as i64).abs();
        }
        out.push(s);
    }
    out
}

/// Serial 2-D SAD template scan — O(Nx·Ny·Mx·My).
pub fn template_scan_2d(
    m: &mut SerialMachine,
    image: &[i32],
    nx: usize,
    ny: usize,
    template: &[i32],
    mx: usize,
    my: usize,
) -> Vec<i64> {
    let mut out = vec![i64::MAX; nx * ny];
    for y in 0..=ny - my {
        for x in 0..=nx - mx {
            let mut s = 0i64;
            for ty in 0..my {
                for tx in 0..mx {
                    m.touch(1);
                    m.compute(1);
                    s += (image[(y + ty) * nx + x + tx] as i64
                        - template[ty * mx + tx] as i64)
                        .abs();
                }
            }
            out[y * nx + x] = s;
        }
    }
    out
}

/// Serial line detection: for every pixel and every direction in the set,
/// walk the messenger path — O(Nx·Ny·D²) total.
pub fn line_detect_serial(
    m: &mut SerialMachine,
    image: &[i32],
    nx: usize,
    ny: usize,
    d: u32,
) -> Vec<i64> {
    use crate::algos::lines::{line_set, messenger_path};
    let set = line_set(d);
    let mut best = vec![0i64; nx * ny];
    for (mx, my) in set {
        let path = messenger_path(mx, my);
        for y in 0..ny {
            for x in 0..nx {
                let mut acc = 0i64;
                for &(px, py) in &path {
                    let cross = px as i64 * my as i64 - py as i64 * mx as i64;
                    if cross == 0 {
                        continue;
                    }
                    let (ax, ay) = (x as i64 + px as i64, y as i64 + py as i64);
                    m.compute(1);
                    if ax >= 0 && ax < nx as i64 && ay >= 0 && ay < ny as i64 {
                        m.touch(1);
                        let v = image[(ay * nx as i64 + ax) as usize] as i64;
                        acc += if cross > 0 { v } else { -v };
                    }
                }
                let i = y * nx + x;
                if acc.abs() > best[i].abs() {
                    best[i] = acc;
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::template::{sad_ref_1d, sad_ref_2d};
    use crate::util::rng::Rng;

    #[test]
    fn convolution_matches_stencil_reference() {
        use crate::algos::local_ops::Stencil;
        let mut rng = Rng::new(121);
        let vals = rng.vec_i32(40, -10, 10);
        let s = Stencil::new(&[1, 2, 1]);
        let mut m = SerialMachine::new();
        let got = convolve_1d(&mut m, &vals, &s.coef);
        assert_eq!(got, s.apply_ref(&vals));
        assert!(m.cost.bus_words > vals.len() as u64);
    }

    #[test]
    fn template_scans_match_references() {
        let mut rng = Rng::new(122);
        let vals = rng.vec_i32(64, 0, 99);
        let tmpl = rng.vec_i32(6, 0, 99);
        let mut m = SerialMachine::new();
        assert_eq!(template_scan_1d(&mut m, &vals, &tmpl), sad_ref_1d(&vals, &tmpl));

        let (nx, ny, mx, my) = (16, 8, 4, 2);
        let img = rng.vec_i32(nx * ny, 0, 99);
        let t2 = rng.vec_i32(mx * my, 0, 99);
        let mut m = SerialMachine::new();
        assert_eq!(
            template_scan_2d(&mut m, &img, nx, ny, &t2, mx, my),
            sad_ref_2d(&img, nx, ny, &t2, mx, my)
        );
        // O(N*M) bus traffic
        assert!(m.cost.bus_words >= ((nx - mx) * (ny - my) * mx * my) as u64);
    }

    #[test]
    fn serial_line_detection_costs_scale_with_image() {
        let mut rng = Rng::new(123);
        let img_small = rng.vec_i32(16 * 16, 0, 50);
        let img_large = rng.vec_i32(32 * 32, 0, 50);
        let mut m1 = SerialMachine::new();
        line_detect_serial(&mut m1, &img_small, 16, 16, 4);
        let mut m2 = SerialMachine::new();
        line_detect_serial(&mut m2, &img_large, 32, 32, 4);
        let ratio = m2.cost.cpu_cycles as f64 / m1.cost.cpu_cycles.max(1) as f64;
        assert!(ratio > 3.0, "serial cost must scale with pixels: {ratio}");
    }
}
