//! Serial substring search baselines (§5.2's comparison targets).
//!
//! Naive O(N·M) scan and Knuth–Morris–Pratt O(N+M) — the latter is the
//! "complicated algorithm requiring pre-processing" the paper contrasts
//! with the content searchable memory's ~M-cycle search.

use super::SerialMachine;

/// Naive scan: returns match end positions (same convention as
/// `ContentSearchableMemory::find_substring`).
pub fn naive_search(m: &mut SerialMachine, text: &[u8], pattern: &[u8]) -> Vec<usize> {
    if pattern.is_empty() || pattern.len() > text.len() {
        return Vec::new();
    }
    let mut hits = Vec::new();
    for start in 0..=text.len() - pattern.len() {
        let mut k = 0;
        while k < pattern.len() {
            m.touch(1); // text byte over the bus
            m.compute(1);
            if text[start + k] != pattern[k] {
                break;
            }
            k += 1;
        }
        if k == pattern.len() {
            hits.push(start + pattern.len() - 1);
        }
    }
    hits
}

/// KMP: O(N + M) with the failure-function preprocessing the paper notes.
pub fn kmp_search(m: &mut SerialMachine, text: &[u8], pattern: &[u8]) -> Vec<usize> {
    if pattern.is_empty() || pattern.len() > text.len() {
        return Vec::new();
    }
    // Failure function (M compute steps).
    let mut fail = vec![0usize; pattern.len()];
    let mut k = 0usize;
    for i in 1..pattern.len() {
        m.compute(1);
        while k > 0 && pattern[k] != pattern[i] {
            m.compute(1);
            k = fail[k - 1];
        }
        if pattern[k] == pattern[i] {
            k += 1;
        }
        fail[i] = k;
    }
    // Scan (N touches).
    let mut hits = Vec::new();
    let mut q = 0usize;
    for (i, &c) in text.iter().enumerate() {
        m.touch(1);
        m.compute(1);
        while q > 0 && pattern[q] != c {
            m.compute(1);
            q = fail[q - 1];
        }
        if pattern[q] == c {
            q += 1;
        }
        if q == pattern.len() {
            hits.push(i);
            q = fail[q - 1];
        }
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn both_find_same_matches() {
        let mut rng = Rng::new(91);
        for _ in 0..50 {
            let n = rng.range(4, 200);
            let text: Vec<u8> = (0..n).map(|_| b'a' + rng.range(0, 3) as u8).collect();
            let mlen = rng.range(1, 5);
            let pattern: Vec<u8> = (0..mlen).map(|_| b'a' + rng.range(0, 3) as u8).collect();
            let mut m1 = SerialMachine::new();
            let mut m2 = SerialMachine::new();
            let a = naive_search(&mut m1, &text, &pattern);
            let b = kmp_search(&mut m2, &text, &pattern);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn matches_cpm_device_results() {
        use crate::device::searchable::ContentSearchableMemory;
        let text = b"abracadabra abradabra";
        let pattern = b"abra";
        let mut m = SerialMachine::new();
        let serial = naive_search(&mut m, text, pattern);
        let mut dev = ContentSearchableMemory::new(text.len());
        dev.load(0, text);
        let cpm = dev.find_substring(pattern, 0, text.len() - 1);
        assert_eq!(serial, cpm);
    }

    #[test]
    fn cost_scaling_naive_vs_kmp() {
        let text = vec![b'a'; 10_000];
        let pattern = vec![b'a'; 50];
        let mut naive = SerialMachine::new();
        naive_search(&mut naive, &text, &pattern);
        let mut kmp = SerialMachine::new();
        kmp_search(&mut kmp, &text, &pattern);
        // Worst case: naive ~N*M, KMP ~N+M.
        assert!(naive.cost.cpu_cycles > 10 * kmp.cost.cpu_cycles);
        assert!(kmp.cost.bus_words <= text.len() as u64 + 10);
    }
}
