//! Database-index baseline (§6.2).
//!
//! "To make the speed of such comparison acceptable, a database index
//! pre-sorts a database field. Even with the help of the index, the
//! instruction cycles of such comparison is still ~M·log(N) (M = average
//! item count per value, N = unique values); the index must be deleted
//! before heavy updates and recreated afterward."
//!
//! This models the index as a sorted (value, row) vector: build O(N log N),
//! point/range query O(log N + hits), and update cost = full rebuild — the
//! operational pain the paper contrasts with the comparable memory's
//! zero-preprocessing compare.

use super::SerialMachine;

/// A sorted index over one i64-valued field.
#[derive(Debug, Clone, Default)]
pub struct SortedIndex {
    entries: Vec<(i64, usize)>,
}

impl SortedIndex {
    /// Build from `(value per row)` — O(N log N) compare/move cost.
    pub fn build(m: &mut SerialMachine, values: &[i64]) -> Self {
        let n = values.len() as u64;
        m.touch(n);
        m.compute(n * (n.max(2)).ilog2() as u64);
        let mut entries: Vec<(i64, usize)> = values
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, i))
            .collect();
        entries.sort_unstable();
        SortedIndex { entries }
    }

    /// Number of indexed rows.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if the index is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Rows with `value == v`: ~log N probe + M hits.
    pub fn eq(&self, m: &mut SerialMachine, v: i64) -> Vec<usize> {
        let n = self.entries.len() as u64;
        m.compute((n.max(2)).ilog2() as u64);
        let start = self.entries.partition_point(|&(x, _)| x < v);
        let mut out = Vec::new();
        let mut i = start;
        while i < self.entries.len() && self.entries[i].0 == v {
            m.touch(1);
            out.push(self.entries[i].1);
            i += 1;
        }
        out.sort_unstable();
        out
    }

    /// Rows with `lo <= value < hi`: ~log N + hits.
    pub fn range(&self, m: &mut SerialMachine, lo: i64, hi: i64) -> Vec<usize> {
        let n = self.entries.len() as u64;
        m.compute(2 * (n.max(2)).ilog2() as u64);
        let start = self.entries.partition_point(|&(x, _)| x < lo);
        let end = self.entries.partition_point(|&(x, _)| x < hi);
        let mut out: Vec<usize> = self.entries[start..end].iter().map(|&(_, r)| r).collect();
        m.touch(out.len() as u64);
        out.sort_unstable();
        out
    }

    /// A field update invalidates the index: the paper's rebuild cost.
    pub fn rebuild_after_update(m: &mut SerialMachine, values: &[i64]) -> Self {
        Self::build(m, values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn eq_and_range_match_scan() {
        let mut rng = Rng::new(101);
        let values: Vec<i64> = (0..500).map(|_| rng.i32_range(0, 50) as i64).collect();
        let mut m = SerialMachine::new();
        let idx = SortedIndex::build(&mut m, &values);
        for probe in [0i64, 7, 25, 49, 99] {
            let got = idx.eq(&mut m, probe);
            let want: Vec<usize> = values
                .iter()
                .enumerate()
                .filter_map(|(i, &v)| if v == probe { Some(i) } else { None })
                .collect();
            assert_eq!(got, want, "probe={probe}");
        }
        let got = idx.range(&mut m, 10, 20);
        let want: Vec<usize> = values
            .iter()
            .enumerate()
            .filter_map(|(i, &v)| if (10..20).contains(&v) { Some(i) } else { None })
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn probe_cost_is_logarithmic_plus_hits() {
        let values: Vec<i64> = (0..1 << 16).map(|i| i as i64).collect();
        let mut m = SerialMachine::new();
        let idx = SortedIndex::build(&mut m, &values);
        m.reset();
        idx.eq(&mut m, 12345);
        assert!(m.cost.cpu_cycles <= 16 + 4, "{}", m.cost.cpu_cycles);
        assert_eq!(m.cost.bus_words, 1);
    }

    #[test]
    fn build_cost_is_n_log_n() {
        let values: Vec<i64> = (0..1024).map(|i| (i * 37 % 1024) as i64).collect();
        let mut m = SerialMachine::new();
        SortedIndex::build(&mut m, &values);
        assert_eq!(m.cost.cpu_cycles, 1024 * 10 + 1024);
    }
}
