//! `cpm` — CLI for the Concurrent Processing Memory reproduction.
//!
//! Subcommands:
//! * `info`                     — device inventory + silicon budgets
//! * `sql --rows N`             — run SQL queries against a generated table
//! * `search --pattern STR`     — substring search demo
//! * `physics`                  — §8 feasibility numbers (Eq 8-1)
//! * `runtime-check`            — execute a trace on the active backend
//!   (the pure-Rust interpreter by default; PJRT with `--features pjrt`)
//!   and cross-check it against the word engine

use cpm::cli::Cli;
use cpm::coordinator::{CpmServer, Request};
use cpm::device::computable::isa::N_REGS;
use cpm::device::computable::{Instr, Opcode, Reg, Src};
use cpm::device::control::ControlUnit;
use cpm::physics;
use cpm::runtime::Backend;
use cpm::sql::Schema;
use cpm::util::rng::Rng;

fn main() {
    let cli = Cli::from_env();
    let result = match cli.command.as_deref() {
        Some("info") => info(&cli),
        Some("sql") => sql(&cli),
        Some("search") => search(&cli),
        Some("physics") => physics_cmd(&cli),
        Some("runtime-check") => runtime_check(&cli),
        _ => {
            eprintln!(
                "usage: cpm <info|sql|search|physics|runtime-check> [--flags]\n\
                 benches: cargo bench (see benches/paper.rs)\n\
                 examples: cargo run --release --example <quickstart|sql_engine|image_pipeline|text_search>"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn info(_cli: &Cli) -> cpm::Result<()> {
    println!("Concurrent Processing Memory (Wang, 2006) — reproduction");
    println!("family members: movable / searchable / comparable / computable");
    for bits in [10usize, 16, 20] {
        let cu = ControlUnit::new(bits);
        let b = cu.silicon_budget();
        println!(
            "control unit for 2^{bits} PEs: decoder {} gates (depth {}), \
             priority-encoder {} gates, parallel-counter {} gates",
            b.decoder.gates, b.decoder.depth, b.priority_encoder.gates, b.parallel_counter.gates
        );
    }
    Ok(())
}

fn sql(cli: &Cli) -> cpm::Result<()> {
    let n = cli.get("rows", 10_000usize);
    let schema = Schema::new(&[("price", 2), ("qty", 1), ("region", 1)])?;
    let mut server = CpmServer::new(schema, n, b"", 1 << 20);
    let mut rng = Rng::new(cli.get("seed", 42u64));
    let rows: Vec<Vec<u64>> = (0..n)
        .map(|_| vec![rng.below(10_000), rng.below(100), rng.below(8)])
        .collect();
    server.load_rows(&rows)?;
    let queries = [
        "SELECT COUNT WHERE price < 5000",
        "SELECT COUNT WHERE price >= 2500 AND price < 7500",
        "SELECT COUNT WHERE qty > 90 OR region = 0",
    ];
    for q in queries {
        let r = server.serve(&Request::Sql(q.to_string()))?;
        println!("{q}\n  -> {r:?}");
    }
    println!(
        "served {} queries; device concurrent cycles {} (vs serial scan ~{} per query)",
        server.metrics.requests,
        server.metrics.device_macro_cycles,
        n
    );
    Ok(())
}

fn search(cli: &Cli) -> cpm::Result<()> {
    let pattern = cli.get_str("pattern").unwrap_or("abra").as_bytes().to_vec();
    let n = cli.get("n", 65_536usize);
    let mut rng = Rng::new(7);
    let mut corpus: Vec<u8> = (0..n).map(|_| b'a' + rng.range(0, 4) as u8).collect();
    corpus[100..100 + pattern.len()].copy_from_slice(&pattern);
    let schema = Schema::new(&[("x", 1)])?;
    let mut server = CpmServer::new(schema, 1, &corpus, 1);
    let r = server.serve(&Request::Search(pattern.clone()))?;
    println!(
        "pattern {:?} in {} bytes -> {:?} (device cycles {})",
        String::from_utf8_lossy(&pattern),
        n,
        r,
        server.metrics.device_macro_cycles
    );
    Ok(())
}

fn physics_cmd(_cli: &Cli) -> cpm::Result<()> {
    let (d, t) = (25e-9, 10e-9);
    println!("Eq 8-1 routing-layer model (D = 25 nm oxide, T = 10 nm copper):");
    for ghz in [0.1f64, 0.4, 1.0, 2.0] {
        let l = physics::max_span_for_clock(ghz * 1e9, d, t);
        println!("  {:>4.1} GHz -> span <= {:.2} mm", ghz, l * 1e3);
    }
    println!(
        "  4 Gbit movable memory at 2 um^2/PE ~ {:.0} mm^2 (paper: ~15x15 mm^2)",
        physics::chip_area_mm2((4u64 << 30) / 8, 2.0)
    );
    println!(
        "  cache depth 4 @ 400 MHz bus -> routing at {:.0} MHz",
        physics::routing_clock_with_cache(400e6, 4) / 1e6
    );
    Ok(())
}

fn runtime_check(cli: &Cli) -> cpm::Result<()> {
    let dir = cli.get_str("artifacts").unwrap_or("artifacts").to_string();
    let mut backend = Backend::new(&dir)?;
    let shapes = backend.available_traces();
    println!("trace shapes from {dir}: {shapes:?}");
    let shape = shapes
        .first()
        .copied()
        .ok_or_else(|| cpm::CpmError::Runtime("no trace shapes available".into()))?;
    // Run the (1 2 1) Gaussian through the backend and cross-check.
    let p = shape.p;
    let mut state = vec![0i32; N_REGS * p];
    for i in 0..p {
        state[Reg::Nb as usize * p + i] = (i % 97) as i32;
    }
    let trace = vec![
        Instr::all(Opcode::Copy, Src::Reg(Reg::Nb), Reg::Op),
        Instr::all(Opcode::Add, Src::Left, Reg::Op),
        Instr::all(Opcode::Copy, Src::Reg(Reg::Op), Reg::Nb),
        Instr::all(Opcode::Add, Src::Right, Reg::Op),
    ];
    let (final_state, counts) = backend.run_trace(shape, &state, &trace)?;
    let mut word = cpm::device::computable::WordEngine::new(p, 16);
    word.set_state(&state);
    word.run(&trace);
    assert_eq!(&final_state[..], &word.state()[..], "backend != word engine");
    println!(
        "runtime-check OK: trace p={} t={} matches the word engine; match counts head {:?}; dispatches {}",
        shape.p,
        shape.t,
        &counts[..4.min(counts.len())],
        backend.dispatches
    );
    Ok(())
}
