//! `cpm` — CLI for the Concurrent Processing Memory reproduction.
//!
//! Subcommands:
//! * `info`                     — device inventory + silicon budgets
//! * `sql --rows N`             — run SQL queries against a generated table
//! * `search --pattern STR`     — substring search demo
//! * `pool --requests N`        — multi-tenant batched serving demo:
//!   device pool, shared passes, overlap makespans, per-tenant metrics
//! * `serve --addr A`           — TCP front-end over a demo server
//!   (batching admission window feeding `handle_batch`)
//! * `client --addr A --sql Q`  — blocking TCP client (`--search`,
//!   `--sum`, `--repeat N` for pipelined bursts, `--tenant`, `--device`;
//!   `--conns N` holds N concurrent connections open as a load-driver
//!   worker: prints `ready`, waits for a line on stdin, then runs the
//!   pipelined op on every connection and prints one line per
//!   connection — the 10k soak spawns these so no single process owns
//!   every fd)
//! * `netbench --max-batch B`   — loopback throughput: N client threads
//!   pipelining against the TCP front-end, reported as requests/sec
//! * `stats --addr A`           — scrape a serving front-end's live
//!   metrics over the wire (`--format text|prometheus`, `--check` to
//!   validate the Prometheus exposition before printing)
//! * `physics`                  — §8 feasibility numbers (Eq 8-1)
//! * `runtime-check`            — execute a trace on the active backend
//!   (the pure-Rust interpreter by default; PJRT with `--features pjrt`)
//!   and cross-check it against the word engine
//!
//! `serve` and `netbench` accept `--reader-cores N` (default 4) to size
//! the fixed set of readiness reader cores multiplexing all connections,
//! `--lanes N` (default 2) to run N parallel dispatcher lanes over
//! the admission window, and `--poll-backend auto|poll|epoll` (default
//! auto: epoll on Linux, poll elsewhere) to pick the poll-ladder rung
//! the reader cores multiplex through — thread count stays flat in the
//! number of connected clients (see DESIGN.md "Serving path").
//!
//! `pool`, `serve`, `netbench`, and `runtime-check` accept `--threads N`
//! to run large dense PE planes sharded across N std worker threads
//! (default 1 = the serial engines), `--backend
//! serial|sharded|simd|pjrt` to pick the compute backend the planes
//! execute on (default sharded; `pjrt` needs `--features pjrt`),
//! `--planes N` to partition the device pool's PE capacity into N
//! placement planes the batch executor overlaps across, and `--dma N`
//! to model the paper's §8 DMA side bus (load phases divided by N in
//! the cost accounting; results unchanged). Every knob rides the one
//! `ServerConfig` precedence ladder: CLI flag > `CPM_*` environment >
//! config default (see DESIGN.md "Configuration & public API").

use std::time::{Duration, Instant};

use cpm::cli::Cli;
use cpm::coordinator::{
    Addressed, ArrayJob, CpmServer, Request, DEFAULT_ARRAY, DEFAULT_CORPUS, DEFAULT_TABLE,
    DEFAULT_TENANT,
};
use cpm::device::computable::isa::N_REGS;
use cpm::device::computable::{Instr, Opcode, Reg, Src};
use cpm::device::control::ControlUnit;
use cpm::net::{CpmClient, NetServer};
use cpm::obs::{export, Metrics};
use cpm::physics;
use cpm::runtime::Backend;
use cpm::sql::Schema;
use cpm::util::rng::Rng;
use cpm::ServerConfig;

fn main() {
    let cli = Cli::from_env();
    let result = match cli.command.as_deref() {
        Some("info") => info(&cli),
        Some("sql") => sql(&cli),
        Some("search") => search(&cli),
        Some("pool") => pool_cmd(&cli),
        Some("serve") => serve_cmd(&cli),
        Some("client") => client_cmd(&cli),
        Some("netbench") => netbench_cmd(&cli),
        Some("stats") => stats_cmd(&cli),
        Some("physics") => physics_cmd(&cli),
        Some("runtime-check") => runtime_check(&cli),
        _ => {
            eprintln!(
                "usage: cpm <info|sql|search|pool|serve|client|netbench|stats|physics|runtime-check> [--flags]\n\
                 benches: cargo bench (see benches/paper.rs)\n\
                 examples: cargo run --release --example <quickstart|sql_engine|image_pipeline|text_search|multi_tenant|tcp_serve>"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn info(_cli: &Cli) -> cpm::Result<()> {
    println!("Concurrent Processing Memory (Wang, 2006) — reproduction");
    println!("family members: movable / searchable / comparable / computable");
    for bits in [10usize, 16, 20] {
        let cu = ControlUnit::new(bits);
        let b = cu.silicon_budget();
        println!(
            "control unit for 2^{bits} PEs: decoder {} gates (depth {}), \
             priority-encoder {} gates, parallel-counter {} gates",
            b.decoder.gates, b.decoder.depth, b.priority_encoder.gates, b.parallel_counter.gates
        );
    }
    Ok(())
}

fn sql(cli: &Cli) -> cpm::Result<()> {
    let n = cli.get("rows", 10_000usize);
    let schema = Schema::new(&[("price", 2), ("qty", 1), ("region", 1)])?;
    let mut server = CpmServer::new(schema, n, b"", 1 << 20);
    let mut rng = Rng::new(cli.get("seed", 42u64));
    let rows: Vec<Vec<u64>> = (0..n)
        .map(|_| vec![rng.below(10_000), rng.below(100), rng.below(8)])
        .collect();
    server.load_rows(&rows)?;
    let queries = [
        "SELECT COUNT WHERE price < 5000",
        "SELECT COUNT WHERE price >= 2500 AND price < 7500",
        "SELECT COUNT WHERE qty > 90 OR region = 0",
    ];
    for q in queries {
        let r = server.serve(&Request::Sql(q.to_string()))?;
        println!("{q}\n  -> {r:?}");
    }
    let m = server.metrics();
    println!(
        "served {} queries; device concurrent cycles {} (vs serial scan ~{} per query)",
        m.requests, m.device_macro_cycles, n
    );
    Ok(())
}

fn search(cli: &Cli) -> cpm::Result<()> {
    let pattern = cli.get_str("pattern").unwrap_or("abra").as_bytes().to_vec();
    let n = cli.get("n", 65_536usize);
    let mut rng = Rng::new(7);
    let mut corpus: Vec<u8> = (0..n).map(|_| b'a' + rng.range(0, 4) as u8).collect();
    corpus[100..100 + pattern.len()].copy_from_slice(&pattern);
    let schema = Schema::new(&[("x", 1)])?;
    let mut server = CpmServer::new(schema, 1, &corpus, 1);
    let r = server.serve(&Request::Search(pattern.clone()))?;
    println!(
        "pattern {:?} in {} bytes -> {:?} (device cycles {})",
        String::from_utf8_lossy(&pattern),
        n,
        r,
        server.metrics().device_macro_cycles
    );
    Ok(())
}

fn pool_cmd(cli: &Cli) -> cpm::Result<()> {
    let n_requests = cli.get("requests", 128usize);
    let rows = cli.get("rows", 4096usize);
    let mut rng = Rng::new(cli.get("seed", 2020u64));

    let cfg = ServerConfig::from_env()
        .capacity(1 << 18)
        .quota(1 << 17)
        .corpus_slack(1024)
        .engine_capacity(1 << 16)
        .with_cli(cli)?;
    let mut pool = cfg.device_pool();
    let schema = Schema::new(&[("price", 2), ("qty", 1)])?;
    pool.create_table("alice", "orders", schema, rows)?;
    let corpus: Vec<u8> = (0..8192).map(|_| b'a' + rng.range(0, 4) as u8).collect();
    pool.create_corpus("bob", "logs", &corpus)?;
    pool.create_array("alice", "readings", &rng.vec_i32(2048, 0, 1000), 2048)?;
    let mut server = cfg.server(pool);
    let table_rows: Vec<Vec<u64>> = (0..rows)
        .map(|_| vec![rng.below(10_000), rng.below(100)])
        .collect();
    server.load_rows_into("alice", "orders", &table_rows)?;

    // A shuffled multi-tenant mix: hot SQL templates, repeated searches,
    // resident-array jobs, ad-hoc loads.
    let mut batch = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let a = match i % 4 {
            0 => Addressed::new(
                "alice",
                "orders",
                Request::Sql(format!(
                    "SELECT COUNT WHERE price < {}",
                    1000 * (1 + i % 8)
                )),
            ),
            1 => Addressed::new(
                "bob",
                "logs",
                Request::Search(match i % 3 {
                    0 => b"abca".to_vec(),
                    1 => b"bcd".to_vec(),
                    _ => b"dd".to_vec(),
                }),
            ),
            2 => Addressed::new("alice", "readings", Request::Array(ArrayJob::Threshold(500))),
            _ => Addressed::for_tenant("bob", Request::Sum(rng.vec_i32(1024, -100, 100))),
        };
        batch.push(a);
    }
    rng.shuffle(&mut batch);
    let responses = server.handle_batch(&batch);
    let errors = responses.iter().filter(|r| r.is_err()).count();

    println!("residents:");
    for r in server.pool().residents() {
        println!(
            "  {}/{} ({}) {} PEs{}",
            r.tenant,
            r.name,
            r.kind,
            r.pes,
            if r.pinned { " [pinned]" } else { "" }
        );
    }
    let m = server.metrics();
    println!(
        "served {} requests ({} errors) in {} batch(es), {} device groups",
        m.requests, errors, m.batches, m.groups_executed
    );
    println!(
        "shared device passes saved: {}; device cycles: {} concurrent + {} exclusive",
        m.shared_passes_saved, m.device_macro_cycles, m.device_exclusive_ops
    );
    println!(
        "makespan: {} cycles back-to-back vs {} overlapped ({:.2}x from §3.1 overlap)",
        m.makespan_serial_cycles,
        m.makespan_overlapped_cycles,
        m.makespan_serial_cycles as f64 / m.makespan_overlapped_cycles.max(1) as f64
    );
    println!(
        "planes: {} plane(s), multi-plane makespan {} cycles, {} cycles saved by the §8 side bus",
        server.pool().plane_count(),
        m.makespan_multi_cycles,
        m.dma_saved_cycles
    );
    for (tenant, t) in &m.per_tenant {
        println!(
            "  tenant {tenant}: {} req, {} err, {} concurrent cycles, {} exclusive ops",
            t.requests, t.errors, t.macro_cycles, t.exclusive_ops
        );
    }
    Ok(())
}

/// Resident scratch-array size on the network demo server (large enough
/// that array jobs run on the sharded plane when `--threads` > 1).
const DEMO_ARRAY_WORDS: usize = 1 << 18;

/// The demo server every network subcommand serves: the `sql` demo table
/// (`default/table`, price/qty/region), a small text corpus
/// (`default/corpus`), and a resident scratch array (`default/array`)
/// whose jobs exercise the dense compute path.
fn demo_server(rows: usize, seed: u64, cfg: &ServerConfig) -> cpm::Result<CpmServer> {
    let schema = Schema::new(&[("price", 2), ("qty", 1), ("region", 1)])?;
    let corpus: &[u8] =
        b"the quick brown fox jumps over the lazy dog; pack my box with five dozen jugs";
    let mut rng = Rng::new(seed);
    let corpus_slack = 1024usize;
    let table_pes = schema.row_size() * rows.max(1);
    // Sized per plane: every demo resident must fit within one plane's
    // share of the capacity, so scale the budget by the plane count.
    let capacity =
        (table_pes + corpus.len() + corpus_slack + DEMO_ARRAY_WORDS + 64) * cfg.pool.planes.max(1);
    let cfg = cfg
        .clone()
        .capacity(capacity)
        .quota(capacity)
        .corpus_slack(corpus_slack);
    let mut pool = cfg.device_pool();
    pool.create_table(DEFAULT_TENANT, DEFAULT_TABLE, schema, rows)?;
    pool.create_corpus(DEFAULT_TENANT, DEFAULT_CORPUS, corpus)?;
    pool.create_array(
        DEFAULT_TENANT,
        DEFAULT_ARRAY,
        &rng.vec_i32(DEMO_ARRAY_WORDS, 0, 1000),
        DEMO_ARRAY_WORDS,
    )?;
    pool.pin(DEFAULT_TENANT, DEFAULT_TABLE, true)?;
    pool.pin(DEFAULT_TENANT, DEFAULT_CORPUS, true)?;
    pool.pin(DEFAULT_TENANT, DEFAULT_ARRAY, true)?;
    let mut server = cfg.server(pool);
    let table_rows: Vec<Vec<u64>> = (0..rows)
        .map(|_| vec![rng.below(10_000), rng.below(100), rng.below(8)])
        .collect();
    server.load_rows(&table_rows)?;
    Ok(server)
}

fn print_wire_metrics(m: &Metrics) {
    let w = &m.wire;
    println!(
        "wire: {} connections, {} requests in {} windows ({} coalesced, max occupancy {}, mean {:.2})",
        w.connections,
        w.window_requests,
        w.windows,
        w.coalesced_windows,
        w.max_window,
        w.mean_occupancy()
    );
    println!(
        "serving: {} requests, {} errors, {} shared passes saved, makespan {} -> {} device cycles",
        m.requests,
        m.errors,
        m.shared_passes_saved,
        m.makespan_serial_cycles,
        m.makespan_overlapped_cycles
    );
}

/// Human-readable summary of a full metrics snapshot: the wire/serving
/// lines plus latency percentiles, the span-stage ledger, and the gauges
/// sampled at the answering scrape.
fn print_stats_text(m: &Metrics) {
    print_wire_metrics(m);
    let lat = m.latency.summary();
    println!(
        "latency: {} samples, mean {:.1} us, p50 <= {} us, p90 <= {} us, p99 <= {} us, max {} us",
        lat.count, lat.mean, lat.p50, lat.p90, lat.p99, lat.max
    );
    let s = &m.spans;
    println!(
        "spans: {} closed; stage totals wait {} us + exec {} us + write {} us = total {} us",
        s.recorded,
        s.wait_ns / 1_000,
        s.exec_ns / 1_000,
        s.write_ns / 1_000,
        s.total_ns / 1_000
    );
    let g = &m.gauges;
    println!(
        "gauges at scrape: queue depth {}, {} worker thread(s) ({}), {} pool dispatches",
        g.queue_depth,
        g.worker_threads,
        if g.worker_busy != 0 { "busy" } else { "idle" },
        g.worker_dispatches
    );
    let depths: Vec<String> = g.lane_queue_depths.iter().map(u64::to_string).collect();
    println!(
        "net tier: {} reader core(s) on {}, {} multiplexed connection(s), lane depths [{}], {} window(s) stolen",
        g.reader_cores,
        if g.poll_backend.is_empty() {
            "-"
        } else {
            g.poll_backend.as_str()
        },
        m.wire.connections_multiplexed,
        depths.join(", "),
        m.wire.windows_stolen
    );
    let used: Vec<String> = g.plane_used_pes.iter().map(u64::to_string).collect();
    println!(
        "planes: {} plane(s), used PEs [{}]; multi-plane makespan {} cycles, {} cycles saved by the §8 side bus",
        g.planes,
        used.join(", "),
        m.makespan_multi_cycles,
        m.dma_saved_cycles
    );
    for (tenant, t) in &m.per_tenant {
        println!(
            "  tenant {tenant}: {} req, {} err, {} concurrent cycles, {} exclusive ops",
            t.requests, t.errors, t.macro_cycles, t.exclusive_ops
        );
    }
    println!("scrapes served: {}", m.scrapes);
}

fn stats_cmd(cli: &Cli) -> cpm::Result<()> {
    let addr = cli
        .get_str("addr")
        .map(str::to_string)
        .or_else(|| cli.positional.first().cloned())
        .unwrap_or_else(|| "127.0.0.1:7070".to_string());
    let mut client = CpmClient::connect(&addr)?;
    let m = client.stats()?;
    match cli.get_str("format").unwrap_or("text") {
        "text" => print_stats_text(&m),
        "prometheus" => {
            let text = export::prometheus(&m);
            if cli.has("check") {
                export::check(&text).map_err(cpm::CpmError::Coordinator)?;
            }
            print!("{text}");
        }
        other => {
            return Err(cpm::CpmError::Coordinator(format!(
                "unknown --format {other:?}; pass text or prometheus"
            )));
        }
    }
    Ok(())
}

fn serve_cmd(cli: &Cli) -> cpm::Result<()> {
    let addr = cli.get_str("addr").unwrap_or("127.0.0.1:7070");
    let rows = cli.get("rows", 4096usize);
    let secs = cli.get("secs", 0u64);
    let cfg = ServerConfig::from_env().addr(addr).with_cli(cli)?;
    let server = demo_server(rows, cli.get("seed", 42u64), &cfg)?;
    let exec = cfg.pool.exec.clone();
    let planes = cfg.pool.planes;
    let window_us = cfg.net.window.max_delay.as_micros();
    let max_batch = cfg.net.window.max_batch;
    let reader_cores = cfg.net.reader_cores;
    let lanes = cfg.net.dispatch_lanes;
    let poll_backend = cfg.net.poll_backend.resolved_name();
    let net = NetServer::spawn(server, cfg.net)?;
    println!(
        "cpm serving on {} ({} reader core(s) on {}, {} lane(s), window {} us, max batch {}, {} exec thread(s), backend {}, {} plane(s), dma x{}); demo devices: default/table ({} rows), default/corpus, default/array ({} words)",
        net.addr(),
        reader_cores,
        poll_backend,
        lanes,
        window_us,
        max_batch,
        exec.threads,
        exec.backend,
        planes,
        exec.dma_speedup.max(1),
        rows,
        DEMO_ARRAY_WORDS
    );
    if secs == 0 {
        println!("running until killed (pass --secs N to auto-stop and print metrics)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));
    let server = net.shutdown();
    print_wire_metrics(&server.metrics());
    Ok(())
}

fn client_cmd(cli: &Cli) -> cpm::Result<()> {
    let addr = cli.get_str("addr").unwrap_or("127.0.0.1:7070");
    let op = if let Some(q) = cli.get_str("sql") {
        Request::Sql(q.to_string())
    } else if let Some(p) = cli.get_str("search") {
        Request::Search(p.as_bytes().to_vec())
    } else if let Some(csv) = cli.get_str("sum") {
        let values: Vec<i32> = csv
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| cpm::CpmError::Coordinator(format!("bad --sum value {s:?}")))
            })
            .collect::<cpm::Result<Vec<i32>>>()?;
        Request::Sum(values)
    } else {
        return Err(cpm::CpmError::Coordinator(
            "pass one of --sql QUERY | --search PATTERN | --sum a,b,c".into(),
        ));
    };
    let device = cli.get_str("device");
    let repeat = cli.get("repeat", 1usize).max(1);
    let conns = cli.get("conns", 1usize).max(1);
    if conns > 1 {
        return client_fanout(addr, &op, cli.get_str("tenant"), device, repeat, conns);
    }
    let mut client = CpmClient::connect(addr)?;
    if let Some(tenant) = cli.get_str("tenant") {
        client.hello(tenant)?;
    }
    if repeat == 1 {
        let response = client.call_addressed(None, device, &op)?;
        println!("{response:?}");
        return Ok(());
    }
    // Pipelined burst: keep a bounded number of requests outstanding so
    // the admission window coalesces them without either side's socket
    // buffer filling up (same policy as CpmClient::pipeline).
    let started = Instant::now();
    let mut sent = 0usize;
    let mut received = 0usize;
    let mut ok = 0usize;
    let mut last = None;
    while received < repeat {
        while sent < repeat && sent - received < cpm::net::MAX_IN_FLIGHT {
            client.send(None, device, &op)?;
            sent += 1;
        }
        let (_, result) = client.recv()?;
        received += 1;
        match result {
            Ok(r) => {
                ok += 1;
                last = Some(r);
            }
            Err(e) => println!("error: {e}"),
        }
    }
    let elapsed = started.elapsed();
    if let Some(r) = last {
        println!("{r:?}");
    }
    println!(
        "{ok}/{repeat} ok in {:.1} ms ({:.0} req/s pipelined)",
        elapsed.as_secs_f64() * 1e3,
        repeat as f64 / elapsed.as_secs_f64().max(1e-9)
    );
    Ok(())
}

/// Connection-scaling worker mode for `cpm client --conns N`: hold N
/// concurrent connections open, report `ready`, wait for one line on
/// stdin (the orchestrator's go signal, sent once every worker is
/// connected), then run the pipelined op on each connection in turn and
/// print one parseable line per connection. The 10k-connection soak
/// spawns a fleet of these so no single process — the test least of
/// all — has to own every fd.
fn client_fanout(
    addr: &str,
    op: &Request,
    tenant: Option<&str>,
    device: Option<&str>,
    repeat: usize,
    conns: usize,
) -> cpm::Result<()> {
    use std::io::{BufRead, Write};
    let mut clients = Vec::with_capacity(conns);
    for _ in 0..conns {
        let mut client = CpmClient::connect(addr)?;
        if let Some(t) = tenant {
            client.hello(t)?;
        }
        clients.push(client);
    }
    let stdout = std::io::stdout();
    {
        let mut out = stdout.lock();
        writeln!(out, "ready {conns}")
            .and_then(|()| out.flush())
            .map_err(|e| cpm::CpmError::Coordinator(format!("reporting ready: {e}")))?;
    }
    let mut go = String::new();
    std::io::stdin()
        .lock()
        .read_line(&mut go)
        .map_err(|e| cpm::CpmError::Coordinator(format!("waiting for go: {e}")))?;
    let mut total_ok = 0usize;
    let mut out = stdout.lock();
    for (i, client) in clients.iter_mut().enumerate() {
        // Bounded-in-flight pipelining (same policy as the single-client
        // --repeat path) rather than CpmClient::pipeline, so --device
        // addressing carries through to fanout mode.
        let mut responses = Vec::with_capacity(repeat);
        let mut sent = 0usize;
        while responses.len() < repeat {
            while sent < repeat && sent - responses.len() < cpm::net::MAX_IN_FLIGHT {
                client.send(None, device, op)?;
                sent += 1;
            }
            let (_, result) = client.recv()?;
            responses.push(result);
        }
        let ok = responses.iter().filter(|r| r.is_ok()).count();
        total_ok += ok;
        // Identical read-only requests must draw identical replies; the
        // orchestrator compares the printed head against a serial
        // in-process replay. Typed errors carry no PartialEq, so the
        // comparison is on the full Debug rendering.
        let rendered: Vec<String> = responses.iter().map(|r| format!("{r:?}")).collect();
        let uniform = rendered.windows(2).all(|w| w[0] == w[1]);
        let head = rendered
            .first()
            .cloned()
            .unwrap_or_else(|| "none".to_string());
        writeln!(out, "conn {i} ok {ok} uniform {} {head}", u8::from(uniform))
            .map_err(|e| cpm::CpmError::Coordinator(format!("reporting conn {i}: {e}")))?;
    }
    writeln!(out, "done {conns} {total_ok}")
        .and_then(|()| out.flush())
        .map_err(|e| cpm::CpmError::Coordinator(format!("reporting done: {e}")))?;
    Ok(())
}

fn netbench_cmd(cli: &Cli) -> cpm::Result<()> {
    let requests = cli.get("requests", 1024usize);
    let clients = cli.get("clients", 8usize).max(1);
    let rows = cli.get("rows", 4096usize);
    let cfg = ServerConfig::from_env().addr("127.0.0.1:0").with_cli(cli)?;
    let server = demo_server(rows, cli.get("seed", 42u64), &cfg)?;
    let exec = cfg.pool.exec.clone();
    let planes = cfg.pool.planes;
    let window_us = cfg.net.window.max_delay.as_micros();
    let max_batch = cfg.net.window.max_batch;
    let reader_cores = cfg.net.reader_cores;
    let lanes = cfg.net.dispatch_lanes;
    let poll_backend = cfg.net.poll_backend.resolved_name();
    let net = NetServer::spawn(server, cfg.net)?;
    let addr = net.addr();
    let per_client = requests.div_ceil(clients);

    let started = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for c in 0..clients {
        handles.push(std::thread::spawn(move || -> cpm::Result<usize> {
            let mut client = CpmClient::connect(addr)?;
            // Read-only mix (hot SQL templates, repeated searches, and
            // resident-array jobs on the dense compute path — the part
            // `--threads` accelerates) so concurrent interleavings
            // cannot change any response.
            let ops: Vec<Request> = (0..per_client)
                .map(|i| match (c + i) % 4 {
                    0 => {
                        let cap = 1000 * (1 + i % 8);
                        Request::Sql(format!("SELECT COUNT WHERE price < {cap}"))
                    }
                    1 => Request::Search(b"the".to_vec()),
                    2 => Request::Array(ArrayJob::Threshold(500)),
                    _ => Request::Sql("SELECT COUNT WHERE qty > 50 OR region = 0".into()),
                })
                .collect();
            let responses = client.pipeline(&ops)?;
            Ok(responses.iter().filter(|r| r.is_ok()).count())
        }));
    }
    let mut ok = 0usize;
    for h in handles {
        ok += h.join().expect("netbench client thread panicked")?;
    }
    let elapsed = started.elapsed();
    let server = net.shutdown();
    let m = server.metrics();
    let total = per_client * clients;
    let rps = total as f64 / elapsed.as_secs_f64().max(1e-9);
    println!(
        "netbench: {total} requests ({ok} ok) from {clients} clients in {:.1} ms",
        elapsed.as_secs_f64() * 1e3
    );
    print_wire_metrics(&m);
    println!(
        "markdown row (backend | threads | reader_cores | poll_backend | conns | max_batch | window_us | requests | req/s | mean window | coalesced):"
    );
    println!(
        "| {} | {} | {} | {} | {} | {} | {} | {} | {:.0} | {:.2} | {} |",
        exec.backend,
        exec.threads,
        reader_cores,
        poll_backend,
        clients,
        max_batch,
        window_us,
        total,
        rps,
        m.wire.mean_occupancy(),
        m.wire.coalesced_windows
    );
    // Machine-readable row for the ROADMAP item-5 perf trajectory
    // (BENCH_net.json): one JSON object per run, appended by the caller.
    if let Some(path) = cli.get_str("json") {
        let host_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let row = format!(
            "{{\"bench\":\"netbench\",\"backend\":\"{}\",\"threads\":{},\"clients\":{},\
             \"reader_cores\":{},\"lanes\":{},\"poll_backend\":\"{}\",\"planes\":{},\"dma\":{},\
             \"max_batch\":{},\"window_us\":{},\"requests\":{},\"ok\":{},\
             \"elapsed_ms\":{:.3},\"req_per_s\":{:.1},\"mean_window\":{:.3},\
             \"coalesced_windows\":{},\"windows_stolen\":{},\"p50_us\":{},\"p99_us\":{},\
             \"max_window\":{},\"shared_passes_saved\":{},\"host_threads\":{}}}\n",
            exec.backend,
            exec.threads,
            clients,
            reader_cores,
            lanes,
            poll_backend,
            planes,
            exec.dma_speedup,
            max_batch,
            window_us,
            total,
            ok,
            elapsed.as_secs_f64() * 1e3,
            rps,
            m.wire.mean_occupancy(),
            m.wire.coalesced_windows,
            m.wire.windows_stolen,
            m.latency.percentile_us(50.0),
            m.latency.percentile_us(99.0),
            m.wire.max_window,
            m.shared_passes_saved,
            host_threads
        );
        std::fs::write(path, row)
            .map_err(|e| cpm::CpmError::Coordinator(format!("writing {path}: {e}")))?;
        println!("wrote bench row to {path}");
    }
    Ok(())
}

fn physics_cmd(_cli: &Cli) -> cpm::Result<()> {
    let (d, t) = (25e-9, 10e-9);
    println!("Eq 8-1 routing-layer model (D = 25 nm oxide, T = 10 nm copper):");
    for ghz in [0.1f64, 0.4, 1.0, 2.0] {
        let l = physics::max_span_for_clock(ghz * 1e9, d, t);
        println!("  {:>4.1} GHz -> span <= {:.2} mm", ghz, l * 1e3);
    }
    println!(
        "  4 Gbit movable memory at 2 um^2/PE ~ {:.0} mm^2 (paper: ~15x15 mm^2)",
        physics::chip_area_mm2((4u64 << 30) / 8, 2.0)
    );
    println!(
        "  cache depth 4 @ 400 MHz bus -> routing at {:.0} MHz",
        physics::routing_clock_with_cache(400e6, 4) / 1e6
    );
    Ok(())
}

fn runtime_check(cli: &Cli) -> cpm::Result<()> {
    let dir = cli.get_str("artifacts").unwrap_or("artifacts").to_string();
    let mut backend = Backend::new(&dir)?;
    // The pure-Rust interpreter honors `--threads` / `--backend`; the
    // PJRT backend parallelizes inside XLA instead.
    #[cfg(not(feature = "pjrt"))]
    backend.set_exec(ServerConfig::from_env().with_cli(cli)?.pool.exec);
    let shapes = backend.available_traces();
    println!("trace shapes from {dir}: {shapes:?}");
    let shape = shapes
        .first()
        .copied()
        .ok_or_else(|| cpm::CpmError::Runtime("no trace shapes available".into()))?;
    // Run the (1 2 1) Gaussian through the backend and cross-check.
    let p = shape.p;
    let mut state = vec![0i32; N_REGS * p];
    for i in 0..p {
        state[Reg::Nb as usize * p + i] = (i % 97) as i32;
    }
    let trace = vec![
        Instr::all(Opcode::Copy, Src::Reg(Reg::Nb), Reg::Op),
        Instr::all(Opcode::Add, Src::Left, Reg::Op),
        Instr::all(Opcode::Copy, Src::Reg(Reg::Op), Reg::Nb),
        Instr::all(Opcode::Add, Src::Right, Reg::Op),
    ];
    let (final_state, counts) = backend.run_trace(shape, &state, &trace)?;
    let mut word = cpm::device::computable::WordEngine::new(p, 16);
    word.set_state(&state);
    word.run(&trace);
    assert_eq!(&final_state[..], &word.state()[..], "backend != word engine");
    println!(
        "runtime-check OK: trace p={} t={} matches the word engine; match counts head {:?}; dispatches {}",
        shape.p,
        shape.t,
        &counts[..4.min(counts.len())],
        backend.dispatches
    );
    Ok(())
}
