//! Error types for the CPM library.

use thiserror::Error;

/// Library-wide error type.
#[derive(Debug, Error)]
pub enum CpmError {
    /// An activation range (Rule 4) that does not fit the device.
    #[error("invalid activation range: start={start} end={end} carry={carry} (device has {pes} PEs)")]
    InvalidRange {
        start: usize,
        end: usize,
        carry: usize,
        pes: usize,
    },

    /// Addressed access outside the device.
    #[error("address {addr} out of range (device has {size} addressable registers)")]
    AddressOutOfRange { addr: usize, size: usize },

    /// Register selector outside the PE register file.
    #[error("invalid register selector {sel}")]
    InvalidRegister { sel: i32 },

    /// Malformed macro instruction.
    #[error("invalid instruction: {0}")]
    InvalidInstruction(String),

    /// Object-manager failures (content movable memory, §4.2).
    #[error("object error: {0}")]
    Object(String),

    /// SQL engine failures (§6.2).
    #[error("sql error: {0}")]
    Sql(String),

    /// PJRT runtime failures (artifact loading / execution).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator / scheduling failures.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// I/O while loading artifacts or workloads.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, CpmError>;
