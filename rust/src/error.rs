//! Error types for the CPM library.
//!
//! Hand-rolled `Display`/`Error` impls — the offline crate set has no
//! `thiserror`, and the default build must stay dependency-free.

use std::fmt;

/// Library-wide error type.
#[derive(Debug)]
pub enum CpmError {
    /// An activation range (Rule 4) that does not fit the device.
    InvalidRange {
        /// Rule 4 start address.
        start: usize,
        /// Rule 4 end address (inclusive).
        end: usize,
        /// Rule 4 carry number.
        carry: usize,
        /// Device size in PEs.
        pes: usize,
    },

    /// Addressed access outside the device.
    AddressOutOfRange {
        /// Offending address.
        addr: usize,
        /// Device size in addressable registers.
        size: usize,
    },

    /// Register selector outside the PE register file.
    InvalidRegister {
        /// Offending selector code.
        sel: i32,
    },

    /// Malformed macro instruction.
    InvalidInstruction(String),

    /// Object-manager failures (content movable memory, §4.2).
    Object(String),

    /// SQL engine failures (§6.2).
    Sql(String),

    /// Runtime failures (trace execution / artifact loading).
    Runtime(String),

    /// Coordinator / scheduling failures.
    Coordinator(String),

    /// Device-pool failures: unknown resident device, wrong device kind,
    /// duplicate names.
    Pool(String),

    /// An admission or edit that does not fit the target device or pool.
    CapacityExceeded {
        /// Device (or pool) being written, as `tenant/name`.
        device: String,
        /// PEs needed to complete the operation.
        needed: usize,
        /// PEs actually available.
        available: usize,
    },

    /// A tenant asking for more resident PEs than its quota allows.
    QuotaExceeded {
        /// Tenant name.
        tenant: String,
        /// PEs the tenant would hold after the admission.
        needed: usize,
        /// The tenant's quota in PEs.
        quota: usize,
    },

    /// Wire-protocol failures in the TCP front-end (malformed frames,
    /// codec mismatches, closed peers).
    Wire(String),

    /// I/O while loading artifacts or workloads.
    Io(std::io::Error),
}

impl fmt::Display for CpmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CpmError::InvalidRange {
                start,
                end,
                carry,
                pes,
            } => write!(
                f,
                "invalid activation range: start={start} end={end} carry={carry} \
                 (device has {pes} PEs)"
            ),
            CpmError::AddressOutOfRange { addr, size } => write!(
                f,
                "address {addr} out of range (device has {size} addressable registers)"
            ),
            CpmError::InvalidRegister { sel } => {
                write!(f, "invalid register selector {sel}")
            }
            CpmError::InvalidInstruction(msg) => write!(f, "invalid instruction: {msg}"),
            CpmError::Object(msg) => write!(f, "object error: {msg}"),
            CpmError::Sql(msg) => write!(f, "sql error: {msg}"),
            CpmError::Runtime(msg) => write!(f, "runtime error: {msg}"),
            CpmError::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            CpmError::Pool(msg) => write!(f, "pool error: {msg}"),
            CpmError::CapacityExceeded {
                device,
                needed,
                available,
            } => write!(
                f,
                "capacity exceeded on {device}: need {needed} PEs, {available} available"
            ),
            CpmError::QuotaExceeded {
                tenant,
                needed,
                quota,
            } => write!(
                f,
                "tenant {tenant} quota exceeded: need {needed} PEs, quota is {quota}"
            ),
            CpmError::Wire(msg) => write!(f, "wire error: {msg}"),
            CpmError::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for CpmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CpmError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CpmError {
    fn from(e: std::io::Error) -> Self {
        CpmError::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, CpmError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_stable() {
        let e = CpmError::InvalidRange {
            start: 2,
            end: 1,
            carry: 1,
            pes: 8,
        };
        assert_eq!(
            e.to_string(),
            "invalid activation range: start=2 end=1 carry=1 (device has 8 PEs)"
        );
        assert_eq!(
            CpmError::AddressOutOfRange { addr: 9, size: 4 }.to_string(),
            "address 9 out of range (device has 4 addressable registers)"
        );
        assert_eq!(
            CpmError::Sql("bad token".into()).to_string(),
            "sql error: bad token"
        );
        assert_eq!(
            CpmError::CapacityExceeded {
                device: "acme/corpus".into(),
                needed: 128,
                available: 64,
            }
            .to_string(),
            "capacity exceeded on acme/corpus: need 128 PEs, 64 available"
        );
        assert_eq!(
            CpmError::QuotaExceeded {
                tenant: "acme".into(),
                needed: 32,
                quota: 16,
            }
            .to_string(),
            "tenant acme quota exceeded: need 32 PEs, quota is 16"
        );
        assert_eq!(
            CpmError::Wire("truncated payload".into()).to_string(),
            "wire error: truncated payload"
        );
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: CpmError = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
