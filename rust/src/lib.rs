//! # Concurrent Processing Memory (CPM)
//!
//! Production-grade reproduction of *Concurrent Processing Memory*
//! (Chengpu Wang, 2006): an in-memory finest-grain massive-SIMD memory
//! family, built as a cycle-level simulator with the paper's four family
//! members, every concurrent algorithm of §4–§7, the serial bus-sharing
//! baselines, and a coordinator that serves application requests against
//! the devices.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod algos;
pub mod baseline;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cycles;
pub mod device;
pub mod error;
pub mod logic;
pub mod net;
pub mod obs;
pub mod physics;
pub mod pool;
pub mod runtime;
pub mod sql;
pub mod util;

pub use config::ServerConfig;
pub use cycles::{ClaimPoint, ConcurrentCost, SerialCost};
pub use error::{CpmError, Result};
