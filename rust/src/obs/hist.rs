//! Bounded log2-bucket histograms.
//!
//! A [`Log2Histogram`] is a fixed array of [`BUCKETS`] counters: bucket 0
//! holds the value 0, bucket `i` (for `i >= 1`) holds values in
//! `[2^(i-1), 2^i)` — i.e. a value lands in the bucket indexed by its bit
//! length. Recording is O(1), memory is a compile-time constant no matter
//! how many samples arrive (the property the old unbounded
//! `samples_us: Vec<u64>` latency store lacked), and two histograms merge
//! by adding buckets — which is what lets per-thread recorders be folded
//! into one ledger without locks on the hot path.
//!
//! Percentiles are nearest-rank over the bucket counts and answer with
//! the containing bucket's upper bound (clamped to the observed max), so
//! a reported percentile is always ≥ the true sample percentile and
//! within 2× of it; the exact `min`, `max`, `count`, and `sum` (hence the
//! mean) are tracked losslessly on the side.
//!
//! [`AtomicHistogram`] is the lock-free sibling used by the shared
//! [`Recorder`](crate::obs::Recorder): `record` from any thread, then
//! [`AtomicHistogram::snapshot`] into a plain [`Log2Histogram`] to read.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count: one bucket per possible `u64` bit length, plus bucket 0
/// for the value 0. Fixed at compile time — the memory bound.
pub const BUCKETS: usize = 65;

/// Bucket index for a value: its bit length (0 for the value 0).
fn bucket_index(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `i` (`u64::MAX` for the last bucket).
fn bucket_ceil(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Inclusive lower bound of bucket `i`.
fn bucket_floor(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// A bounded histogram over `u64` samples (see the module docs for the
/// bucket scheme). `count` is derived from the buckets, so a merge or a
/// racy atomic snapshot can never disagree with itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Log2Histogram {
    buckets: [u64; BUCKETS],
    sum: u64,
    /// Smallest recorded value; `u64::MAX` while empty.
    min: u64,
    max: u64,
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Log2Histogram::new()
    }
}

/// Summary of a histogram readable without the histogram itself: the
/// snapshot-based percentile surface (reads take `&self`, never `&mut`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Percentiles {
    /// Samples recorded.
    pub count: u64,
    /// Exact mean (sum and count are tracked losslessly).
    pub mean: f64,
    /// Exact minimum (0 while empty).
    pub min: u64,
    /// Exact maximum (0 while empty).
    pub max: u64,
    /// Median (bucket-resolved; see [`Log2Histogram::percentile`]).
    pub p50: u64,
    /// 90th percentile.
    pub p90: u64,
    /// 99th percentile.
    pub p99: u64,
}

impl Log2Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Log2Histogram {
            buckets: [0; BUCKETS],
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Rebuild from wire parts (bucket counts plus the exact side
    /// stats). An all-zero bucket array yields an empty histogram
    /// regardless of `min`/`max`.
    pub fn from_parts(buckets: [u64; BUCKETS], sum: u64, min: u64, max: u64) -> Self {
        let mut h = Log2Histogram {
            buckets,
            sum,
            min,
            max,
        };
        if h.count() == 0 {
            h.min = u64::MAX;
            h.max = 0;
            h.sum = 0;
        }
        h
    }

    /// Record one sample. O(1), no allocation.
    pub fn record(&mut self, v: u64) {
        self.record_n(v, 1);
    }

    /// Record `n` samples of the same value (amortized batch latency).
    pub fn record_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.buckets[bucket_index(v)] += n;
        self.sum = self.sum.saturating_add(v.saturating_mul(n));
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one (cross-thread merge).
    pub fn merge(&mut self, other: &Log2Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// The bucket counters (bucket `i` covers `[2^(i-1), 2^i)`).
    pub fn buckets(&self) -> &[u64; BUCKETS] {
        &self.buckets
    }

    /// Samples recorded (derived from the buckets).
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Exact sum of all recorded values (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Exact minimum recorded value (0 while empty).
    pub fn min(&self) -> u64 {
        if self.max == 0 && self.min == u64::MAX {
            0
        } else {
            self.min
        }
    }

    /// Exact maximum recorded value (0 while empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean (0.0 while empty).
    pub fn mean(&self) -> f64 {
        let count = self.count();
        if count == 0 {
            return 0.0;
        }
        self.sum as f64 / count as f64
    }

    /// Nearest-rank percentile (`p` in `0..=100`): the rank is
    /// `round(p/100 * (count-1))`; rank 0 answers the exact min, the top
    /// rank the exact max, and anything between answers the containing
    /// bucket's upper bound clamped to the max — always ≥ the true
    /// sample percentile and within 2× of it.
    pub fn percentile(&self, p: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * (count - 1) as f64).round() as u64;
        if rank == 0 {
            return self.min();
        }
        if rank >= count - 1 {
            return self.max;
        }
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen > rank {
                return bucket_ceil(i).min(self.max);
            }
        }
        self.max
    }

    /// Snapshot summary: count, mean, min/max, p50/p90/p99.
    pub fn summary(&self) -> Percentiles {
        Percentiles {
            count: self.count(),
            mean: self.mean(),
            min: self.min(),
            max: self.max(),
            p50: self.percentile(50.0),
            p90: self.percentile(90.0),
            p99: self.percentile(99.0),
        }
    }

    /// Inclusive upper bound of bucket `i` (`u64::MAX` for the last) —
    /// the `le` boundary the exporter publishes.
    pub fn bucket_bound(i: usize) -> u64 {
        bucket_ceil(i)
    }
}

/// Lock-free histogram for concurrent recording: same bucket scheme as
/// [`Log2Histogram`], all counters relaxed atomics. Recording is O(1)
/// and wait-free; [`AtomicHistogram::snapshot`] reads a plain
/// [`Log2Histogram`] that is racy across fields under concurrent writes
/// but internally consistent (its count derives from its buckets) and
/// monotone between quiesced points.
#[derive(Debug)]
pub struct AtomicHistogram {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram::new()
    }
}

impl AtomicHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        AtomicHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample from any thread.
    pub fn record(&self, v: u64) {
        self.record_n(v, 1);
    }

    /// Record `n` samples of the same value from any thread.
    pub fn record_n(&self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.buckets[bucket_index(v)].fetch_add(n, Ordering::Relaxed);
        self.sum.fetch_add(v.saturating_mul(n), Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Read the current contents as a plain histogram.
    pub fn snapshot(&self) -> Log2Histogram {
        let buckets = std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed));
        Log2Histogram::from_parts(
            buckets,
            self.sum.load(Ordering::Relaxed),
            self.min.load(Ordering::Relaxed),
            self.max.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::thread;

    #[test]
    fn bucket_scheme_is_bit_length() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), 64);
        for i in 0..BUCKETS {
            assert_eq!(bucket_index(bucket_floor(i)), i);
            assert_eq!(bucket_index(bucket_ceil(i)), i);
        }
    }

    #[test]
    fn records_exact_side_stats() {
        let mut h = Log2Histogram::new();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            h.record(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.sum(), 550);
        assert_eq!(h.min(), 10);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 55.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_semantics_are_bucketed_nearest_rank() {
        // Values 10..=100 land in buckets 4 (10), 5 (20, 30), 6 (40..60),
        // 7 (70..100). Rank 0 and the top rank answer exactly; middle
        // ranks answer the containing bucket's ceiling.
        let mut h = Log2Histogram::new();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            h.record(v);
        }
        assert_eq!(h.percentile(0.0), 10);
        assert_eq!(h.percentile(50.0), 63); // rank 5 -> bucket 6 ceil
        assert_eq!(h.percentile(90.0), 100); // rank 8 -> bucket 7, clamped
        assert_eq!(h.percentile(99.0), 100); // top rank -> exact max
        assert_eq!(h.percentile(100.0), 100);
        assert!(h.percentile(50.0) <= h.percentile(99.0));
    }

    #[test]
    fn empty_histogram_answers_zero() {
        let h = Log2Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.summary(), Percentiles::default());
    }

    #[test]
    fn merge_equals_serial_recount() {
        let mut rng = Rng::new(77);
        let values: Vec<u64> = (0..10_000).map(|_| rng.below(1 << 30)).collect();
        let mut serial = Log2Histogram::new();
        for &v in &values {
            serial.record(v);
        }
        let mut merged = Log2Histogram::new();
        for chunk in values.chunks(997) {
            let mut part = Log2Histogram::new();
            for &v in chunk {
                part.record(v);
            }
            merged.merge(&part);
        }
        assert_eq!(merged, serial);
    }

    #[test]
    fn memory_is_bounded_at_a_million_records() {
        // The bound itself is the type's size: fixed buckets plus three
        // side counters, no heap, regardless of sample count.
        assert_eq!(
            std::mem::size_of::<Log2Histogram>(),
            (BUCKETS + 3) * std::mem::size_of::<u64>()
        );
        let mut rng = Rng::new(2024);
        let mut h = Log2Histogram::new();
        let mut reference: Vec<u64> = Vec::with_capacity(1_000_000);
        for _ in 0..1_000_000 {
            let v = rng.below(1 << 20);
            h.record(v);
            reference.push(v);
        }
        assert_eq!(h.count(), 1_000_000);
        reference.sort_unstable();
        // Property: every percentile answers >= the true sample
        // percentile and <= 2x it (bucket ceilings double at worst).
        for p in [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9] {
            let rank = ((p / 100.0) * (reference.len() - 1) as f64).round() as usize;
            let truth = reference[rank];
            let got = h.percentile(p);
            assert!(got >= truth, "p{p}: {got} < true {truth}");
            assert!(got <= 2 * truth.max(1), "p{p}: {got} > 2x true {truth}");
        }
        assert_eq!(h.percentile(0.0), reference[0]);
        assert_eq!(h.percentile(100.0), *reference.last().unwrap());
    }

    #[test]
    fn atomic_histogram_matches_serial_across_threads() {
        let atomic = AtomicHistogram::new();
        let mut serial = Log2Histogram::new();
        let per_thread = 4096u64;
        let threads = 4u64;
        for t in 0..threads {
            let mut rng = Rng::new(300 + t);
            for _ in 0..per_thread {
                serial.record(rng.below(1 << 24));
            }
        }
        thread::scope(|scope| {
            for t in 0..threads {
                let atomic = &atomic;
                scope.spawn(move || {
                    let mut rng = Rng::new(300 + t);
                    for _ in 0..per_thread {
                        atomic.record(rng.below(1 << 24));
                    }
                });
            }
        });
        assert_eq!(atomic.snapshot(), serial);
    }

    #[test]
    fn from_parts_normalizes_empty() {
        let h = Log2Histogram::from_parts([0; BUCKETS], 0, 0, 0);
        assert_eq!(h, Log2Histogram::new());
    }
}
