//! Plain-data snapshot of the recorder: the [`Metrics`] struct and its
//! parts. A snapshot is an owned value — every read (`percentile_us`,
//! `mean_us`, the exporter) takes `&self`, so callers never need `&mut`
//! access to the server or any lock to look at numbers. The field
//! surface extends the pre-observability `Metrics`/`WireMetrics`/
//! `TenantMetrics` trio with span ([`SpanStats`]) and gauge
//! ([`GaugeStats`]) blocks.

use std::collections::BTreeMap;
use std::time::Duration;

use super::hist::{Log2Histogram, Percentiles};
use super::recorder::SpanEvent;
use super::Stage;

/// Request-latency distribution in microseconds, backed by a bounded
/// [`Log2Histogram`] (the old implementation kept every sample in an
/// unbounded `Vec<u64>`; this one is fixed-size no matter the traffic).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LatencyStats {
    hist: Log2Histogram,
}

impl LatencyStats {
    /// Record one request latency.
    pub fn record(&mut self, d: Duration) {
        self.hist.record(d.as_micros() as u64);
    }

    /// Wrap an already-populated histogram (recorder snapshots).
    pub fn from_hist(hist: Log2Histogram) -> Self {
        LatencyStats { hist }
    }

    /// The underlying microsecond histogram.
    pub fn hist(&self) -> &Log2Histogram {
        &self.hist
    }

    /// Latencies recorded.
    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    /// Nearest-rank percentile in microseconds, bucket-resolved (see
    /// [`Log2Histogram::percentile`]). Reads take `&self`.
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.hist.percentile(p)
    }

    /// Exact mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        self.hist.mean()
    }

    /// Snapshot summary (count, mean, min/max, p50/p90/p99).
    pub fn summary(&self) -> Percentiles {
        self.hist.summary()
    }
}

/// Wire-level counters from the TCP front-end.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireMetrics {
    /// Connections accepted by the listener.
    pub connections: u64,
    /// Admission windows dispatched.
    pub windows: u64,
    /// Windows that coalesced more than one request.
    pub coalesced_windows: u64,
    /// Largest window dispatched.
    pub max_window: u64,
    /// Requests admitted through windows (sum of window sizes).
    pub window_requests: u64,
    /// Connections taken over by a readiness reader core (each also
    /// counts in `connections`; the two diverge only for connections
    /// dropped at the accept cap before a core adopted them).
    pub connections_multiplexed: u64,
    /// Ready admission windows executed by a dispatcher lane other than
    /// the one they arrived on (work stealing between lanes).
    pub windows_stolen: u64,
}

impl WireMetrics {
    /// Mean requests per dispatched window (0.0 before any window).
    pub fn mean_occupancy(&self) -> f64 {
        if self.windows == 0 {
            return 0.0;
        }
        self.window_requests as f64 / self.windows as f64
    }
}

/// Per-tenant counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantMetrics {
    /// Requests attributed to the tenant.
    pub requests: u64,
    /// Failed requests attributed to the tenant.
    pub errors: u64,
    /// Modeled device macro-op cycles the tenant consumed.
    pub macro_cycles: u64,
    /// Exclusive (serializing) device ops the tenant issued.
    pub exclusive_ops: u64,
}

/// Request-path span ledger: per-stage nanosecond totals that decompose
/// exactly (`wait + exec + write == total`, enforced by construction in
/// `net/server.rs`), per-stage microsecond histograms, and the most
/// recent span events from the fixed-capacity ring.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SpanStats {
    /// Spans recorded since the server started.
    pub recorded: u64,
    /// Total nanoseconds spent in admission-window wait.
    pub wait_ns: u64,
    /// Total nanoseconds spent in batch execution.
    pub exec_ns: u64,
    /// Total nanoseconds spent encoding + writing replies.
    pub write_ns: u64,
    /// Total end-to-end nanoseconds (equals the sum of the above).
    pub total_ns: u64,
    /// Per-stage wall-time histograms in microseconds, indexed by
    /// [`Stage`] (`wait`, `exec`, `write`, `total`).
    pub stages: [Log2Histogram; 4],
    /// Most recent span events, oldest first (bounded by
    /// [`SPAN_RING_CAPACITY`](super::SPAN_RING_CAPACITY)).
    pub recent: Vec<SpanEvent>,
}

impl SpanStats {
    /// The wall-time histogram for one stage.
    pub fn stage(&self, s: Stage) -> &Log2Histogram {
        &self.stages[s as usize]
    }
}

/// Point-in-time gauges sampled when a scrape is answered.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GaugeStats {
    /// Requests waiting across all admission lanes at sample time.
    pub queue_depth: u64,
    /// Worker-pool threads alive.
    pub worker_threads: u64,
    /// 1 if a worker-pool dispatch was in flight at sample time.
    pub worker_busy: u64,
    /// Worker-pool dispatches completed since startup.
    pub worker_dispatches: u64,
    /// Readiness reader cores multiplexing connections (0 when the
    /// server is not fronted by the TCP tier).
    pub reader_cores: u64,
    /// Requests waiting per dispatcher lane at sample time, indexed by
    /// lane id (empty when the server is not fronted by the TCP tier).
    pub lane_queue_depths: Vec<u64>,
    /// PE planes the device pool is partitioned into (1 = single-plane).
    pub planes: u64,
    /// PEs claimed by residents per plane at the last sample, indexed by
    /// plane id.
    pub plane_used_pes: Vec<u64>,
    /// The poll-ladder rung the reader cores resolved to (`"poll"` /
    /// `"epoll"`; empty when the server is not fronted by the TCP
    /// tier).
    pub poll_backend: String,
}

/// Snapshot of every served-path counter, histogram, span, and gauge.
/// Produced by [`Recorder::snapshot`](super::Recorder::snapshot); plain
/// data, cheap to clone, serializable over the wire as a `Stats` reply.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Metrics {
    /// Requests served (ok or error).
    pub requests: u64,
    /// Requests that returned an error.
    pub errors: u64,
    /// Modeled device macro-op cycles consumed.
    pub device_macro_cycles: u64,
    /// Exclusive (serializing) device ops issued.
    pub device_exclusive_ops: u64,
    /// Batches admitted through `handle_batch`.
    pub batches: u64,
    /// Requests that arrived inside those batches.
    pub batched_requests: u64,
    /// Device passes saved by shared-execution grouping.
    pub shared_passes_saved: u64,
    /// Execution groups the batch planner formed.
    pub groups_executed: u64,
    /// Modeled serial makespan (cycles) of all executed groups.
    pub makespan_serial_cycles: u64,
    /// Modeled overlapped makespan (cycles) of all executed groups.
    pub makespan_overlapped_cycles: u64,
    /// Modeled multi-plane makespan (cycles) of all executed groups —
    /// never exceeds `makespan_overlapped_cycles`.
    pub makespan_multi_cycles: u64,
    /// Cycles the §8 DMA side bus shaved off the multi-plane makespan
    /// (0 while `dma_speedup` is off).
    pub dma_saved_cycles: u64,
    /// Wall nanoseconds spent forming batch groups (plan phase).
    pub group_plan_ns: u64,
    /// Stats scrapes answered.
    pub scrapes: u64,
    /// Per-tenant counters, keyed by tenant name.
    pub per_tenant: BTreeMap<String, TenantMetrics>,
    /// Request-latency distribution (microseconds).
    pub latency: LatencyStats,
    /// TCP front-end counters.
    pub wire: WireMetrics,
    /// Request-path span ledger.
    pub spans: SpanStats,
    /// Point-in-time gauges from the latest scrape sample.
    pub gauges: GaugeStats,
}

impl Metrics {
    /// The (created-on-first-use) counters for one tenant.
    pub fn tenant(&mut self, name: &str) -> &mut TenantMetrics {
        self.per_tenant.entry(name.to_string()).or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles_read_through_shared_ref() {
        let mut lat = LatencyStats::default();
        for us in [100u64, 200, 300, 400, 500] {
            lat.record(Duration::from_micros(us));
        }
        // Reads take &self: no &mut needed once recorded.
        let lat = &lat;
        assert_eq!(lat.count(), 5);
        assert!((lat.mean_us() - 300.0).abs() < 1e-9);
        assert_eq!(lat.percentile_us(0.0), 100);
        assert_eq!(lat.percentile_us(100.0), 500);
        // Middle ranks answer the containing log2 bucket's ceiling.
        assert!(lat.percentile_us(50.0) >= 300);
        assert!(lat.percentile_us(50.0) <= 511);
        let s = lat.summary();
        assert_eq!(s.count, 5);
        assert_eq!(s.min, 100);
        assert_eq!(s.max, 500);
    }

    #[test]
    fn wire_mean_occupancy() {
        let w = WireMetrics {
            windows: 4,
            window_requests: 10,
            ..WireMetrics::default()
        };
        assert!((w.mean_occupancy() - 2.5).abs() < 1e-9);
        assert_eq!(WireMetrics::default().mean_occupancy(), 0.0);
    }

    #[test]
    fn tenant_entry_created_on_first_use() {
        let mut m = Metrics::default();
        m.tenant("alice").requests += 1;
        m.tenant("alice").requests += 1;
        m.tenant("bob").errors += 1;
        assert_eq!(m.per_tenant["alice"].requests, 2);
        assert_eq!(m.per_tenant["bob"].errors, 1);
        assert_eq!(m.per_tenant.len(), 2);
    }

    #[test]
    fn span_stats_stage_indexing() {
        let mut s = SpanStats::default();
        s.stages[Stage::Exec as usize].record(42);
        assert_eq!(s.stage(Stage::Exec).count(), 1);
        assert_eq!(s.stage(Stage::Wait).count(), 0);
    }
}
