//! Prometheus exposition-format text exporter over a [`Metrics`]
//! snapshot, plus the scrape checker CI runs against a live server.
//!
//! Every registered metric always appears in the output — `# HELP` and
//! `# TYPE` lines are emitted even when a family has no series yet (for
//! example the per-tenant counters before any tenant exists) — so
//! [`check`] can insist on the complete [`METRIC_NAMES`] roster against
//! any scrape, including one taken before traffic.

use std::fmt::Write as _;

use super::hist::Log2Histogram;
use super::snapshot::Metrics;
use super::Stage;

/// Every metric name the exporter emits. [`check`] requires each of
/// these to appear in a scrape; the CI scrape leg runs that check
/// against a live `cpm serve`.
pub const METRIC_NAMES: [&str; 38] = [
    "cpm_requests_total",
    "cpm_errors_total",
    "cpm_batches_total",
    "cpm_batched_requests_total",
    "cpm_groups_executed_total",
    "cpm_shared_passes_saved_total",
    "cpm_device_macro_cycles_total",
    "cpm_device_exclusive_ops_total",
    "cpm_makespan_serial_cycles_total",
    "cpm_makespan_overlapped_cycles_total",
    "cpm_makespan_multi_cycles_total",
    "cpm_dma_saved_cycles_total",
    "cpm_group_plan_ns_total",
    "cpm_connections_total",
    "cpm_connections_multiplexed_total",
    "cpm_windows_total",
    "cpm_coalesced_windows_total",
    "cpm_window_requests_total",
    "cpm_windows_stolen_total",
    "cpm_stats_scrapes_total",
    "cpm_spans_recorded_total",
    "cpm_span_stage_ns_total",
    "cpm_window_max_occupancy",
    "cpm_queue_depth",
    "cpm_reader_cores",
    "cpm_poll_backend",
    "cpm_lane_queue_depth",
    "cpm_planes",
    "cpm_plane_used_pes",
    "cpm_worker_threads",
    "cpm_worker_busy",
    "cpm_worker_dispatches_total",
    "cpm_request_latency_us",
    "cpm_span_stage_us",
    "cpm_tenant_requests_total",
    "cpm_tenant_errors_total",
    "cpm_tenant_macro_cycles_total",
    "cpm_tenant_exclusive_ops_total",
];

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    header(out, name, "counter", help);
    let _ = writeln!(out, "{name} {v}");
}

fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    header(out, name, "gauge", help);
    let _ = writeln!(out, "{name} {v}");
}

/// Escape a label value per the exposition format.
fn escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Emit one histogram series (`_bucket`/`_sum`/`_count`) with optional
/// extra labels such as `stage="wait"`. Buckets are cumulative up to the
/// highest non-empty log2 bucket, then `+Inf`.
fn hist_series(out: &mut String, name: &str, labels: &str, h: &Log2Histogram) {
    let sep = if labels.is_empty() { "" } else { "," };
    let hi = h
        .buckets()
        .iter()
        .take(64)
        .rposition(|&n| n > 0)
        .unwrap_or(0);
    let mut cum = 0u64;
    for (i, &n) in h.buckets().iter().enumerate().take(hi + 1) {
        cum += n;
        let le = Log2Histogram::bucket_bound(i);
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{le}\"}} {cum}");
    }
    let count = h.count();
    let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {count}");
    let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum());
    let _ = writeln!(out, "{name}_count{{{labels}}} {count}");
}

/// Render a snapshot in Prometheus exposition format.
pub fn prometheus(m: &Metrics) -> String {
    let mut out = String::new();
    counter(&mut out, "cpm_requests_total", "Requests served (ok or error).", m.requests);
    counter(&mut out, "cpm_errors_total", "Requests that returned an error.", m.errors);
    counter(&mut out, "cpm_batches_total", "Batches admitted through handle_batch.", m.batches);
    counter(
        &mut out,
        "cpm_batched_requests_total",
        "Requests that arrived inside batches.",
        m.batched_requests,
    );
    counter(
        &mut out,
        "cpm_groups_executed_total",
        "Execution groups formed by the batch planner.",
        m.groups_executed,
    );
    counter(
        &mut out,
        "cpm_shared_passes_saved_total",
        "Device passes saved by shared-execution grouping.",
        m.shared_passes_saved,
    );
    counter(
        &mut out,
        "cpm_device_macro_cycles_total",
        "Modeled device macro-op cycles consumed.",
        m.device_macro_cycles,
    );
    counter(
        &mut out,
        "cpm_device_exclusive_ops_total",
        "Exclusive (serializing) device ops issued.",
        m.device_exclusive_ops,
    );
    counter(
        &mut out,
        "cpm_makespan_serial_cycles_total",
        "Modeled serial makespan of executed groups (cycles).",
        m.makespan_serial_cycles,
    );
    counter(
        &mut out,
        "cpm_makespan_overlapped_cycles_total",
        "Modeled overlapped makespan of executed groups (cycles).",
        m.makespan_overlapped_cycles,
    );
    counter(
        &mut out,
        "cpm_makespan_multi_cycles_total",
        "Modeled multi-plane makespan of executed groups (cycles).",
        m.makespan_multi_cycles,
    );
    counter(
        &mut out,
        "cpm_dma_saved_cycles_total",
        "Cycles the DMA side bus shaved off the multi-plane makespan.",
        m.dma_saved_cycles,
    );
    counter(
        &mut out,
        "cpm_group_plan_ns_total",
        "Wall nanoseconds spent forming batch groups.",
        m.group_plan_ns,
    );
    counter(
        &mut out,
        "cpm_connections_total",
        "Connections accepted by the listener.",
        m.wire.connections,
    );
    counter(
        &mut out,
        "cpm_connections_multiplexed_total",
        "Connections adopted by a readiness reader core.",
        m.wire.connections_multiplexed,
    );
    counter(&mut out, "cpm_windows_total", "Admission windows dispatched.", m.wire.windows);
    counter(
        &mut out,
        "cpm_coalesced_windows_total",
        "Windows that coalesced more than one request.",
        m.wire.coalesced_windows,
    );
    counter(
        &mut out,
        "cpm_window_requests_total",
        "Requests admitted through windows.",
        m.wire.window_requests,
    );
    counter(
        &mut out,
        "cpm_windows_stolen_total",
        "Ready windows executed by a lane other than the one they arrived on.",
        m.wire.windows_stolen,
    );
    counter(&mut out, "cpm_stats_scrapes_total", "Stats scrapes answered.", m.scrapes);
    counter(
        &mut out,
        "cpm_spans_recorded_total",
        "Request-path spans recorded.",
        m.spans.recorded,
    );
    header(
        &mut out,
        "cpm_span_stage_ns_total",
        "counter",
        "Wall nanoseconds per request-path stage (wait + exec + write = total).",
    );
    let stage_ns = [m.spans.wait_ns, m.spans.exec_ns, m.spans.write_ns, m.spans.total_ns];
    for s in Stage::ALL {
        let _ = writeln!(
            out,
            "cpm_span_stage_ns_total{{stage=\"{}\"}} {}",
            s.name(),
            stage_ns[s as usize]
        );
    }
    gauge(
        &mut out,
        "cpm_window_max_occupancy",
        "Largest admission window dispatched.",
        m.wire.max_window as f64,
    );
    gauge(
        &mut out,
        "cpm_queue_depth",
        "Requests waiting across all admission lanes at sample time.",
        m.gauges.queue_depth as f64,
    );
    gauge(
        &mut out,
        "cpm_reader_cores",
        "Readiness reader cores multiplexing connections.",
        m.gauges.reader_cores as f64,
    );
    // Info-style gauge: the resolved rung rides in the label, the value
    // says whether a TCP tier is serving at all.
    header(
        &mut out,
        "cpm_poll_backend",
        "gauge",
        "Poll-ladder rung the reader cores resolved to (1 = serving).",
    );
    let _ = writeln!(
        out,
        "cpm_poll_backend{{backend=\"{}\"}} {}",
        escape(&m.gauges.poll_backend),
        u64::from(!m.gauges.poll_backend.is_empty())
    );
    header(
        &mut out,
        "cpm_lane_queue_depth",
        "gauge",
        "Requests waiting per dispatcher lane at sample time.",
    );
    for (lane, depth) in m.gauges.lane_queue_depths.iter().enumerate() {
        let _ = writeln!(out, "cpm_lane_queue_depth{{lane=\"{lane}\"}} {depth}");
    }
    gauge(
        &mut out,
        "cpm_planes",
        "PE planes the device pool is partitioned into.",
        m.gauges.planes as f64,
    );
    header(
        &mut out,
        "cpm_plane_used_pes",
        "gauge",
        "PEs claimed by residents per plane at the last sample.",
    );
    for (plane, used) in m.gauges.plane_used_pes.iter().enumerate() {
        let _ = writeln!(out, "cpm_plane_used_pes{{plane=\"{plane}\"}} {used}");
    }
    gauge(
        &mut out,
        "cpm_worker_threads",
        "Worker-pool threads alive.",
        m.gauges.worker_threads as f64,
    );
    gauge(
        &mut out,
        "cpm_worker_busy",
        "1 if a worker-pool dispatch was in flight at sample time.",
        m.gauges.worker_busy as f64,
    );
    counter(
        &mut out,
        "cpm_worker_dispatches_total",
        "Worker-pool dispatches completed.",
        m.gauges.worker_dispatches,
    );
    header(&mut out, "cpm_request_latency_us", "histogram", "Request latency (microseconds).");
    hist_series(&mut out, "cpm_request_latency_us", "", m.latency.hist());
    header(
        &mut out,
        "cpm_span_stage_us",
        "histogram",
        "Per-stage request-path wall time (microseconds).",
    );
    for s in Stage::ALL {
        let labels = format!("stage=\"{}\"", s.name());
        hist_series(&mut out, "cpm_span_stage_us", &labels, m.spans.stage(s));
    }
    header(&mut out, "cpm_tenant_requests_total", "counter", "Requests per tenant.");
    for (name, t) in &m.per_tenant {
        let _ = writeln!(
            out,
            "cpm_tenant_requests_total{{tenant=\"{}\"}} {}",
            escape(name),
            t.requests
        );
    }
    header(&mut out, "cpm_tenant_errors_total", "counter", "Errors per tenant.");
    for (name, t) in &m.per_tenant {
        let _ = writeln!(
            out,
            "cpm_tenant_errors_total{{tenant=\"{}\"}} {}",
            escape(name),
            t.errors
        );
    }
    header(
        &mut out,
        "cpm_tenant_macro_cycles_total",
        "counter",
        "Modeled device macro-op cycles per tenant.",
    );
    for (name, t) in &m.per_tenant {
        let _ = writeln!(
            out,
            "cpm_tenant_macro_cycles_total{{tenant=\"{}\"}} {}",
            escape(name),
            t.macro_cycles
        );
    }
    header(
        &mut out,
        "cpm_tenant_exclusive_ops_total",
        "counter",
        "Exclusive device ops per tenant.",
    );
    for (name, t) in &m.per_tenant {
        let _ = writeln!(
            out,
            "cpm_tenant_exclusive_ops_total{{tenant=\"{}\"}} {}",
            escape(name),
            t.exclusive_ops
        );
    }
    out
}

/// Validate a scrape: every non-comment line must parse as
/// `name[{labels}] value`, at least one series must be present, and
/// every name in [`METRIC_NAMES`] must appear somewhere in the text.
/// Returns the first problem found.
pub fn check(text: &str) -> Result<(), String> {
    let mut series = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name_part, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value: {line:?}", lineno + 1))?;
        let name = match name_part.split_once('{') {
            Some((n, labels)) => {
                if !labels.ends_with('}') {
                    return Err(format!("line {}: unclosed labels: {line:?}", lineno + 1));
                }
                n
            }
            None => name_part,
        };
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        {
            return Err(format!("line {}: bad metric name: {name:?}", lineno + 1));
        }
        if value.parse::<f64>().is_err() {
            return Err(format!("line {}: bad value {value:?}", lineno + 1));
        }
        series += 1;
    }
    if series == 0 {
        return Err("no series in scrape".to_string());
    }
    for name in METRIC_NAMES {
        if !text.contains(name) {
            return Err(format!("scrape is missing metric: {name}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::{Recorder, SpanEvent};

    #[test]
    fn empty_snapshot_exports_every_metric_name() {
        let text = prometheus(&Metrics::default());
        check(&text).expect("empty snapshot must still scrape clean");
        for name in METRIC_NAMES {
            assert!(text.contains(name), "missing {name}");
        }
        // No TCP tier: the info gauge reports an empty rung at 0.
        assert!(text.contains("cpm_poll_backend{backend=\"\"} 0"));
    }

    #[test]
    fn populated_snapshot_round_trips_the_checker() {
        let r = Recorder::new();
        r.requests_served(3);
        r.batch_admitted(3);
        r.record_latency_n(std::time::Duration::from_micros(100), 3);
        r.record_span(SpanEvent::closed(1_000, 2_000, 500, 3, 42));
        r.tenant("alice", |t| t.requests += 3);
        r.window_dispatched(3);
        r.connection_multiplexed();
        r.set_reader_cores(4);
        r.sample_lane_depths(&[2, 0]);
        r.set_planes(2);
        r.sample_planes(&[320, 64]);
        r.record_multi(480, 80);
        r.window_stolen();
        r.set_poll_backend("epoll");
        let text = prometheus(&r.snapshot());
        check(&text).expect("populated snapshot must scrape clean");
        assert!(text.contains("cpm_requests_total 3"));
        assert!(text.contains("cpm_connections_multiplexed_total 1"));
        assert!(text.contains("cpm_reader_cores 4"));
        assert!(text.contains("cpm_poll_backend{backend=\"epoll\"} 1"));
        assert!(text.contains("cpm_lane_queue_depth{lane=\"0\"} 2"));
        assert!(text.contains("cpm_lane_queue_depth{lane=\"1\"} 0"));
        assert!(text.contains("cpm_planes 2"));
        assert!(text.contains("cpm_plane_used_pes{plane=\"0\"} 320"));
        assert!(text.contains("cpm_plane_used_pes{plane=\"1\"} 64"));
        assert!(text.contains("cpm_makespan_multi_cycles_total 480"));
        assert!(text.contains("cpm_dma_saved_cycles_total 80"));
        assert!(text.contains("cpm_windows_stolen_total 1"));
        assert!(text.contains("cpm_tenant_requests_total{tenant=\"alice\"} 3"));
        assert!(text.contains("cpm_span_stage_ns_total{stage=\"exec\"} 2000"));
        assert!(text.contains("cpm_request_latency_us_bucket{le=\"127\"} 3"));
        assert!(text.contains("cpm_request_latency_us_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("cpm_request_latency_us_sum{} 300"));
        assert!(text.contains("cpm_request_latency_us_count{} 3"));
        assert!(text.contains("cpm_span_stage_us_bucket{stage=\"wait\",le=\"1\"} 1"));
    }

    #[test]
    fn tenant_labels_are_escaped() {
        let mut m = Metrics::default();
        m.tenant("we\"ird\\name").requests = 1;
        let text = prometheus(&m);
        check(&text).expect("escaped labels must scrape clean");
        assert!(text.contains("cpm_tenant_requests_total{tenant=\"we\\\"ird\\\\name\"} 1"));
    }

    #[test]
    fn checker_rejects_garbage() {
        assert!(check("").is_err());
        assert!(check("cpm_requests_total not-a-number\n").is_err());
        assert!(check("bad name{ 1\n").is_err());
        // Valid lines but an incomplete metric roster still fails.
        assert!(check("cpm_requests_total 1\n").is_err());
    }
}
