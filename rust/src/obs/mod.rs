//! End-to-end observability: bounded histograms, a lock-cheap metrics
//! recorder, request-path span tracing, and a Prometheus-format exporter.
//!
//! The paper's claims are cycle counts; the serving stack's claims are
//! wall clock. This module is where the two ledgers meet so they can be
//! compared side by side:
//!
//! * [`hist`] — fixed-size log2-bucket histograms: O(1) record, bounded
//!   memory no matter how many samples arrive, mergeable across threads,
//!   with an atomic sibling for lock-free recording.
//! * [`recorder`] — the [`Recorder`]: every serving-path counter
//!   (requests, errors, device cycles, batching gains, wire activity) as
//!   relaxed atomics, plus the span ring that traces each request through
//!   its `wait` → `exec` → `write` stages with wall time *and* modeled
//!   device cycles per window.
//! * [`snapshot`] — the plain-data [`Metrics`] snapshot the recorder
//!   produces: the pre-existing `Metrics`/`WireMetrics`/`TenantMetrics`
//!   field surface, extended with [`SpanStats`] and [`GaugeStats`], and
//!   readable through `&` (no server lock, no `&mut`).
//! * [`export`] — the Prometheus exposition-format text exporter and the
//!   scrape checker CI runs against a live server.
//!
//! One [`Recorder`] is shared by every layer: the coordinator records
//! request/device/batch counters, the TCP front-end records wire counters
//! and spans, readers answer `Stats` scrapes from it directly (the
//! dispatcher is never blocked by a scrape), and `cpm stats` renders the
//! snapshot.
#![warn(missing_docs)]

pub mod export;
pub mod hist;
pub mod recorder;
pub mod snapshot;

pub use hist::{AtomicHistogram, Log2Histogram, Percentiles, BUCKETS};
pub use recorder::{Recorder, SpanEvent, SPAN_RING_CAPACITY};
pub use snapshot::{GaugeStats, LatencyStats, Metrics, SpanStats, TenantMetrics, WireMetrics};

/// Request-path span stages, in ledger order. Each served request is
/// decomposed into admission-window wait, batch execution, and reply
/// write; `Total` is their exact sum (one shared arrival stamp, no
/// independent clock reads — see `net/server.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Admission-window wait: frame decoded → window dispatched.
    Wait = 0,
    /// Batch execution: window dispatched → `handle_batch` returned.
    Exec = 1,
    /// Reply encode + write back to the peer.
    Write = 2,
    /// End to end: `wait + exec + write`, exactly.
    Total = 3,
}

/// Stage names as exported (`cpm_span_stage_us{stage="..."}`) and as
/// documented in DESIGN.md's span stage table (CI greps this list).
pub const STAGE_NAMES: [&str; 4] = ["wait", "exec", "write", "total"];

impl Stage {
    /// Every stage, in ledger order.
    pub const ALL: [Stage; 4] = [Stage::Wait, Stage::Exec, Stage::Write, Stage::Total];

    /// The exported name of this stage.
    pub fn name(self) -> &'static str {
        STAGE_NAMES[self as usize]
    }
}
