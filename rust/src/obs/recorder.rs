//! The shared [`Recorder`]: one instance per server, written by every
//! serving layer, read by scrapes.
//!
//! All counters are relaxed atomics and every write path takes `&self`,
//! so the coordinator, the dispatcher thread, and the per-connection
//! reader threads all record into the same instance without a lock on
//! the hot path. The only mutexes guard the per-tenant map and the span
//! ring — both touched once per request at most, never per device op —
//! and a scrape reads everything through [`Recorder::snapshot`] without
//! ever taking the dispatcher's time.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use super::hist::AtomicHistogram;
use super::snapshot::{GaugeStats, LatencyStats, Metrics, SpanStats, TenantMetrics, WireMetrics};
use super::Stage;

/// Capacity of the span event ring: the most recent spans kept for the
/// `recent` block of a snapshot. Fixed — span memory is bounded no
/// matter how long the server runs.
pub const SPAN_RING_CAPACITY: usize = 512;

/// One closed request-path span: per-stage wall time plus the modeled
/// device cycles the window consumed, so the wall-clock ledger and the
/// paper's cycle ledger can be compared per request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpanEvent {
    /// Nanoseconds from frame decode to window dispatch.
    pub wait_ns: u64,
    /// Nanoseconds from window dispatch to `handle_batch` return.
    pub exec_ns: u64,
    /// Nanoseconds encoding + writing the reply.
    pub write_ns: u64,
    /// End-to-end nanoseconds: exactly `wait_ns + exec_ns + write_ns`.
    pub total_ns: u64,
    /// Requests in the admission window this span rode in.
    pub window_len: u32,
    /// Modeled device cycles the window's batch consumed.
    pub device_cycles: u64,
}

impl SpanEvent {
    /// Close a span from its three stage durations; `total_ns` is their
    /// sum by construction, so the ledger decomposes exactly.
    pub fn closed(
        wait_ns: u64,
        exec_ns: u64,
        write_ns: u64,
        window_len: u32,
        device_cycles: u64,
    ) -> Self {
        SpanEvent {
            wait_ns,
            exec_ns,
            write_ns,
            total_ns: wait_ns + exec_ns + write_ns,
            window_len,
            device_cycles,
        }
    }
}

/// Fixed-capacity overwrite-oldest ring of span events.
#[derive(Debug, Default)]
struct SpanRing {
    events: Vec<SpanEvent>,
    next: usize,
}

impl SpanRing {
    fn push(&mut self, ev: SpanEvent) {
        if self.events.len() < SPAN_RING_CAPACITY {
            self.events.push(ev);
        } else {
            self.events[self.next] = ev;
        }
        self.next = (self.next + 1) % SPAN_RING_CAPACITY;
    }

    /// Events oldest-first.
    fn recent(&self) -> Vec<SpanEvent> {
        if self.events.len() < SPAN_RING_CAPACITY {
            return self.events.clone();
        }
        let mut out = Vec::with_capacity(SPAN_RING_CAPACITY);
        out.extend_from_slice(&self.events[self.next..]);
        out.extend_from_slice(&self.events[..self.next]);
        out
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// The live metrics registry shared by every serving layer. All methods
/// take `&self`; share it as an `Arc<Recorder>`.
#[derive(Debug, Default)]
pub struct Recorder {
    // Coordinator counters.
    requests: AtomicU64,
    errors: AtomicU64,
    device_macro_cycles: AtomicU64,
    device_exclusive_ops: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    shared_passes_saved: AtomicU64,
    groups_executed: AtomicU64,
    makespan_serial_cycles: AtomicU64,
    makespan_overlapped_cycles: AtomicU64,
    makespan_multi_cycles: AtomicU64,
    dma_saved_cycles: AtomicU64,
    group_plan_ns: AtomicU64,
    // Wire counters.
    connections: AtomicU64,
    connections_multiplexed: AtomicU64,
    windows: AtomicU64,
    coalesced_windows: AtomicU64,
    max_window: AtomicU64,
    window_requests: AtomicU64,
    windows_stolen: AtomicU64,
    scrapes: AtomicU64,
    // Span stage totals (nanoseconds).
    spans_recorded: AtomicU64,
    span_wait_ns: AtomicU64,
    span_exec_ns: AtomicU64,
    span_write_ns: AtomicU64,
    span_total_ns: AtomicU64,
    // Gauges (sampled at scrape time).
    queue_depth: AtomicU64,
    worker_threads: AtomicU64,
    worker_busy: AtomicU64,
    worker_dispatches: AtomicU64,
    reader_cores: AtomicU64,
    planes: AtomicU64,
    // Distributions.
    latency_us: AtomicHistogram,
    stage_us: [AtomicHistogram; 4],
    // Cold-path state.
    tenants: Mutex<BTreeMap<String, TenantMetrics>>,
    ring: Mutex<SpanRing>,
    lane_depths: Mutex<Vec<u64>>,
    plane_used: Mutex<Vec<u64>>,
    poll_backend: Mutex<String>,
}

impl Recorder {
    /// Fresh recorder with every counter at zero.
    pub fn new() -> Self {
        Recorder::default()
    }

    /// A batch entered `handle_batch` carrying `n` requests.
    pub fn batch_admitted(&self, n: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` requests finished (ok or error).
    pub fn requests_served(&self, n: u64) {
        self.requests.fetch_add(n, Ordering::Relaxed);
    }

    /// One request returned an error.
    pub fn request_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Update one tenant's counters under the tenant-map lock.
    pub fn tenant(&self, name: &str, update: impl FnOnce(&mut TenantMetrics)) {
        let mut tenants = lock(&self.tenants);
        update(tenants.entry(name.to_string()).or_default());
    }

    /// Modeled device cost consumed by a request.
    pub fn device_cost(&self, macro_cycles: u64, exclusive_ops: u64) {
        self.device_macro_cycles.fetch_add(macro_cycles, Ordering::Relaxed);
        self.device_exclusive_ops.fetch_add(exclusive_ops, Ordering::Relaxed);
    }

    /// Batch-plan outcome: grouping gains, makespans, and the wall time
    /// the planner itself took.
    pub fn batch_totals(
        &self,
        shared_passes_saved: u64,
        groups: u64,
        makespan_serial: u64,
        makespan_overlapped: u64,
        plan_ns: u64,
    ) {
        self.shared_passes_saved.fetch_add(shared_passes_saved, Ordering::Relaxed);
        self.groups_executed.fetch_add(groups, Ordering::Relaxed);
        self.makespan_serial_cycles.fetch_add(makespan_serial, Ordering::Relaxed);
        self.makespan_overlapped_cycles.fetch_add(makespan_overlapped, Ordering::Relaxed);
        self.group_plan_ns.fetch_add(plan_ns, Ordering::Relaxed);
    }

    /// Multi-plane batch outcome: the placed makespan and the cycles the
    /// §8 DMA side bus shaved off it.
    pub fn record_multi(&self, makespan_multi: u64, dma_saved: u64) {
        self.makespan_multi_cycles.fetch_add(makespan_multi, Ordering::Relaxed);
        self.dma_saved_cycles.fetch_add(dma_saved, Ordering::Relaxed);
    }

    /// Record the same per-request latency for `n` requests (amortized
    /// share of a batch).
    pub fn record_latency_n(&self, d: Duration, n: u64) {
        self.latency_us.record_n(d.as_micros() as u64, n);
    }

    /// The listener accepted a connection.
    pub fn connection_accepted(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    /// A readiness reader core adopted an accepted connection into its
    /// multiplexed set.
    pub fn connection_multiplexed(&self) {
        self.connections_multiplexed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record how many reader cores the serving tier is running (a
    /// startup-time gauge, so `cpm stats` shows the live topology).
    pub fn set_reader_cores(&self, n: u64) {
        self.reader_cores.store(n, Ordering::Relaxed);
    }

    /// An admission window of `n` requests was dispatched.
    pub fn window_dispatched(&self, n: u64) {
        self.windows.fetch_add(1, Ordering::Relaxed);
        self.window_requests.fetch_add(n, Ordering::Relaxed);
        if n > 1 {
            self.coalesced_windows.fetch_add(1, Ordering::Relaxed);
        }
        self.max_window.fetch_max(n, Ordering::Relaxed);
    }

    /// A ready admission window was executed by a dispatcher lane other
    /// than the one it arrived on.
    pub fn window_stolen(&self) {
        self.windows_stolen.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one closed request-path span.
    pub fn record_span(&self, ev: SpanEvent) {
        self.spans_recorded.fetch_add(1, Ordering::Relaxed);
        self.span_wait_ns.fetch_add(ev.wait_ns, Ordering::Relaxed);
        self.span_exec_ns.fetch_add(ev.exec_ns, Ordering::Relaxed);
        self.span_write_ns.fetch_add(ev.write_ns, Ordering::Relaxed);
        self.span_total_ns.fetch_add(ev.total_ns, Ordering::Relaxed);
        self.stage_us[Stage::Wait as usize].record(ev.wait_ns / 1_000);
        self.stage_us[Stage::Exec as usize].record(ev.exec_ns / 1_000);
        self.stage_us[Stage::Write as usize].record(ev.write_ns / 1_000);
        self.stage_us[Stage::Total as usize].record(ev.total_ns / 1_000);
        lock(&self.ring).push(ev);
    }

    /// Store the point-in-time gauges a scrape observed.
    pub fn sample_gauges(
        &self,
        queue_depth: u64,
        worker_threads: u64,
        worker_busy: u64,
        worker_dispatches: u64,
    ) {
        self.queue_depth.store(queue_depth, Ordering::Relaxed);
        self.worker_threads.store(worker_threads, Ordering::Relaxed);
        self.worker_busy.store(worker_busy, Ordering::Relaxed);
        self.worker_dispatches.store(worker_dispatches, Ordering::Relaxed);
    }

    /// Store the per-dispatcher-lane queue depths a scrape observed.
    pub fn sample_lane_depths(&self, depths: &[u64]) {
        let mut lanes = lock(&self.lane_depths);
        lanes.clear();
        lanes.extend_from_slice(depths);
    }

    /// Record how many PE planes the device pool is partitioned into (a
    /// startup-time gauge, like `set_reader_cores`).
    pub fn set_planes(&self, n: u64) {
        self.planes.store(n, Ordering::Relaxed);
    }

    /// Record which poll-ladder rung the reader cores resolved to
    /// (`"poll"` / `"epoll"`) — a startup-time gauge like
    /// `set_reader_cores`; empty while no TCP tier serves.
    pub fn set_poll_backend(&self, name: &str) {
        let mut backend = lock(&self.poll_backend);
        backend.clear();
        backend.push_str(name);
    }

    /// Store the per-plane resident PE occupancy observed after a batch
    /// (or at scrape time).
    pub fn sample_planes(&self, used: &[u64]) {
        let mut planes = lock(&self.plane_used);
        planes.clear();
        planes.extend_from_slice(used);
    }

    /// A stats scrape was answered.
    pub fn scraped(&self) {
        self.scrapes.fetch_add(1, Ordering::Relaxed);
    }

    /// Total modeled device cycles so far (macro + exclusive). Device
    /// costs are only recorded inside `handle_batch`, which every
    /// dispatcher lane calls while holding the server exclusively, so a
    /// delta taken around one `handle_batch` call under that access is
    /// exact even with multiple lanes.
    pub fn device_cycles_total(&self) -> u64 {
        self.device_macro_cycles.load(Ordering::Relaxed)
            + self.device_exclusive_ops.load(Ordering::Relaxed)
    }

    /// Read everything into a plain-data [`Metrics`] snapshot. Never
    /// blocks recording threads beyond the two cold-path mutexes.
    pub fn snapshot(&self) -> Metrics {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed);
        Metrics {
            requests: load(&self.requests),
            errors: load(&self.errors),
            device_macro_cycles: load(&self.device_macro_cycles),
            device_exclusive_ops: load(&self.device_exclusive_ops),
            batches: load(&self.batches),
            batched_requests: load(&self.batched_requests),
            shared_passes_saved: load(&self.shared_passes_saved),
            groups_executed: load(&self.groups_executed),
            makespan_serial_cycles: load(&self.makespan_serial_cycles),
            makespan_overlapped_cycles: load(&self.makespan_overlapped_cycles),
            makespan_multi_cycles: load(&self.makespan_multi_cycles),
            dma_saved_cycles: load(&self.dma_saved_cycles),
            group_plan_ns: load(&self.group_plan_ns),
            scrapes: load(&self.scrapes),
            per_tenant: lock(&self.tenants).clone(),
            latency: LatencyStats::from_hist(self.latency_us.snapshot()),
            wire: WireMetrics {
                connections: load(&self.connections),
                windows: load(&self.windows),
                coalesced_windows: load(&self.coalesced_windows),
                max_window: load(&self.max_window),
                window_requests: load(&self.window_requests),
                connections_multiplexed: load(&self.connections_multiplexed),
                windows_stolen: load(&self.windows_stolen),
            },
            spans: SpanStats {
                recorded: load(&self.spans_recorded),
                wait_ns: load(&self.span_wait_ns),
                exec_ns: load(&self.span_exec_ns),
                write_ns: load(&self.span_write_ns),
                total_ns: load(&self.span_total_ns),
                stages: std::array::from_fn(|i| self.stage_us[i].snapshot()),
                recent: lock(&self.ring).recent(),
            },
            gauges: GaugeStats {
                queue_depth: load(&self.queue_depth),
                worker_threads: load(&self.worker_threads),
                worker_busy: load(&self.worker_busy),
                worker_dispatches: load(&self.worker_dispatches),
                reader_cores: load(&self.reader_cores),
                lane_queue_depths: lock(&self.lane_depths).clone(),
                planes: load(&self.planes),
                plane_used_pes: lock(&self.plane_used).clone(),
                poll_backend: lock(&self.poll_backend).clone(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Stage;

    #[test]
    fn counters_land_in_the_snapshot() {
        let r = Recorder::new();
        r.batch_admitted(3);
        r.requests_served(3);
        r.request_error();
        r.device_cost(120, 2);
        r.batch_totals(5, 2, 900, 640, 1_500);
        r.record_multi(480, 80);
        r.window_stolen();
        r.record_latency_n(Duration::from_micros(250), 3);
        r.connection_accepted();
        r.window_dispatched(3);
        r.window_dispatched(1);
        r.scraped();
        r.tenant("alice", |t| t.requests += 3);
        let m = r.snapshot();
        assert_eq!(m.requests, 3);
        assert_eq!(m.errors, 1);
        assert_eq!(m.batches, 1);
        assert_eq!(m.batched_requests, 3);
        assert_eq!(m.device_macro_cycles, 120);
        assert_eq!(m.device_exclusive_ops, 2);
        assert_eq!(m.shared_passes_saved, 5);
        assert_eq!(m.groups_executed, 2);
        assert_eq!(m.makespan_serial_cycles, 900);
        assert_eq!(m.makespan_overlapped_cycles, 640);
        assert_eq!(m.makespan_multi_cycles, 480);
        assert_eq!(m.dma_saved_cycles, 80);
        assert_eq!(m.group_plan_ns, 1_500);
        assert_eq!(m.scrapes, 1);
        assert_eq!(m.latency.count(), 3);
        assert_eq!(m.wire.connections, 1);
        assert_eq!(m.wire.windows, 2);
        assert_eq!(m.wire.coalesced_windows, 1);
        assert_eq!(m.wire.max_window, 3);
        assert_eq!(m.wire.window_requests, 4);
        assert_eq!(m.wire.windows_stolen, 1);
        assert_eq!(m.per_tenant["alice"].requests, 3);
    }

    #[test]
    fn spans_decompose_exactly_and_fill_stage_hists() {
        let r = Recorder::new();
        r.record_span(SpanEvent::closed(1_000, 2_000, 500, 2, 77));
        r.record_span(SpanEvent::closed(4_000, 8_000, 1_000, 1, 33));
        let m = r.snapshot();
        assert_eq!(m.spans.recorded, 2);
        assert_eq!(m.spans.wait_ns + m.spans.exec_ns + m.spans.write_ns, m.spans.total_ns);
        assert_eq!(m.spans.total_ns, 3_500 + 13_000);
        assert_eq!(m.spans.stage(Stage::Exec).count(), 2);
        assert_eq!(m.spans.stage(Stage::Exec).sum(), 2 + 8);
        assert_eq!(m.spans.recent.len(), 2);
        assert_eq!(m.spans.recent[1].device_cycles, 33);
    }

    #[test]
    fn span_ring_is_bounded_and_keeps_the_newest() {
        let r = Recorder::new();
        let extra = 100u64;
        for i in 0..SPAN_RING_CAPACITY as u64 + extra {
            r.record_span(SpanEvent::closed(i, 0, 0, 1, 0));
        }
        let m = r.snapshot();
        assert_eq!(m.spans.recorded, SPAN_RING_CAPACITY as u64 + extra);
        assert_eq!(m.spans.recent.len(), SPAN_RING_CAPACITY);
        assert_eq!(m.spans.recent[0].wait_ns, extra);
        assert_eq!(
            m.spans.recent.last().unwrap().wait_ns,
            SPAN_RING_CAPACITY as u64 + extra - 1
        );
    }

    #[test]
    fn gauges_store_latest_sample() {
        let r = Recorder::new();
        r.sample_gauges(7, 4, 1, 99);
        r.sample_gauges(0, 4, 0, 120);
        r.set_reader_cores(4);
        r.sample_lane_depths(&[5, 2]);
        r.sample_lane_depths(&[0, 3]);
        r.set_planes(2);
        r.sample_planes(&[100, 40]);
        r.sample_planes(&[90, 50]);
        r.set_poll_backend("poll");
        r.set_poll_backend("epoll");
        let g = r.snapshot().gauges;
        assert_eq!(g.queue_depth, 0);
        assert_eq!(g.worker_threads, 4);
        assert_eq!(g.worker_busy, 0);
        assert_eq!(g.worker_dispatches, 120);
        assert_eq!(g.reader_cores, 4);
        assert_eq!(g.lane_queue_depths, vec![0, 3]);
        assert_eq!(g.planes, 2);
        assert_eq!(g.plane_used_pes, vec![90, 50]);
        assert_eq!(g.poll_backend, "epoll", "latest set wins");
    }

    #[test]
    fn multiplexed_connections_count_separately_from_accepts() {
        let r = Recorder::new();
        r.connection_accepted();
        r.connection_accepted();
        // Only one of the two accepts was adopted by a reader core
        // (the other was dropped at the accept cap).
        r.connection_multiplexed();
        let w = r.snapshot().wire;
        assert_eq!(w.connections, 2);
        assert_eq!(w.connections_multiplexed, 1);
    }
}
