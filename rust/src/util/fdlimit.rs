//! fd-rlimit orchestration for the connection soaks: a hand-rolled
//! `getrlimit(2)`/`setrlimit(2)` shim (no `libc` crate, keeping the
//! zero-dependency pledge — same pattern as `net::poll`'s FFI) that
//! raises the soft `RLIMIT_NOFILE` toward a requested floor, bounded by
//! the hard cap.
//!
//! The soaks use it to *request* the fd budget they need before
//! deciding to skip: a 10k-connection run asks for ~2.5 fds of headroom
//! per connection, raises the soft limit as far as the hard limit
//! allows, and only skips if even that falls short. Child processes
//! (the spawned `cpm client` workers) inherit the raised limit.

#[cfg(unix)]
mod sys {
    use std::os::raw::c_int;

    /// `struct rlimit` with 64-bit `rlim_t` — the layout on every
    /// 64-bit unix this crate targets (glibc/musl x86-64 and aarch64,
    /// the BSDs, macOS).
    #[repr(C)]
    #[derive(Debug, Clone, Copy)]
    pub struct RLimit {
        pub cur: u64,
        pub max: u64,
    }

    // The RLIMIT_NOFILE resource number: 8 on the BSD-derived targets,
    // 7 on Linux.
    #[cfg(any(target_os = "macos", target_os = "ios", target_os = "freebsd"))]
    pub const RLIMIT_NOFILE: c_int = 8;
    #[cfg(not(any(target_os = "macos", target_os = "ios", target_os = "freebsd")))]
    pub const RLIMIT_NOFILE: c_int = 7;

    extern "C" {
        pub fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
        pub fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
    }
}

/// The process's current soft limit on open file descriptors (an
/// effectively-infinite sentinel value when unlimited). Returns 0 if
/// the limit cannot be read.
#[cfg(unix)]
pub fn nofile_soft() -> u64 {
    let mut r = sys::RLimit { cur: 0, max: 0 };
    let rc = unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut r) };
    if rc != 0 {
        return 0;
    }
    r.cur
}

/// The process's current soft limit on open file descriptors. Non-unix
/// targets have no rlimits; report effectively unlimited.
#[cfg(not(unix))]
pub fn nofile_soft() -> u64 {
    u64::MAX
}

/// Raise the soft fd limit to at least `want`, bounded by the hard cap,
/// and return the resulting soft limit. Never lowers the limit; a
/// refusal (hard cap below `want`, or `setrlimit` denied) leaves the
/// old limit in place and reports it, so callers can decide to skip —
/// after having actually *asked* for what they need. Child processes
/// spawned afterwards inherit the raised limit.
#[cfg(unix)]
pub fn raise_nofile(want: u64) -> u64 {
    let mut r = sys::RLimit { cur: 0, max: 0 };
    if unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut r) } != 0 {
        return 0;
    }
    if r.cur >= want {
        return r.cur;
    }
    let target = want.min(r.max);
    if target <= r.cur {
        return r.cur;
    }
    let attempt = sys::RLimit {
        cur: target,
        max: r.max,
    };
    if unsafe { sys::setrlimit(sys::RLIMIT_NOFILE, &attempt) } != 0 {
        return r.cur;
    }
    target
}

/// Raise the soft fd limit to at least `want`. Non-unix targets have no
/// rlimits; report effectively unlimited.
#[cfg(not(unix))]
pub fn raise_nofile(_want: u64) -> u64 {
    u64::MAX
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_a_positive_soft_limit() {
        // Every environment this runs in can open *some* files.
        assert!(nofile_soft() > 0);
    }

    #[test]
    fn raising_below_current_is_a_reported_noop() {
        let cur = nofile_soft();
        assert_eq!(raise_nofile(1), cur, "no-op must report the live limit");
        assert_eq!(nofile_soft(), cur, "limit must be untouched");
    }

    #[test]
    fn raise_never_lowers_and_reports_the_outcome() {
        let before = nofile_soft();
        let after = raise_nofile(before.saturating_add(16));
        assert!(after >= before, "raise must never lower the limit");
        assert_eq!(nofile_soft(), after, "report must match the live limit");
    }
}
