//! Property-based testing mini-framework.
//!
//! The offline crate set has no `proptest`/`quickcheck`, so the library
//! carries a small deterministic property checker: generate `iters` random
//! cases from a seeded [`Rng`](super::rng::Rng), run the property, and on
//! failure report the failing seed/case and attempt bisection-style
//! shrinking over the generator's size parameter.

use super::rng::Rng;

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of random cases.
    pub iters: u32,
    /// Base seed; case `i` uses seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            iters: 256,
            base_seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` on `iters` cases drawn by `gen`. Panics with a reproducible
/// seed report on the first failure.
pub fn forall<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
{
    for i in 0..cfg.iters {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed at iter {i} (seed {seed:#x}): {msg}\ncase: {case:#?}"
            );
        }
    }
}

/// Like [`forall`] but the generator takes a *size* hint that grows over the
/// run (small cases first, so failures are naturally small).
pub fn forall_sized<T, G, P>(cfg: Config, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> PropResult,
{
    for i in 0..cfg.iters {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let size = 1 + (i as usize * 64) / cfg.iters.max(1) as usize;
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng, size);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed at iter {i} (seed {seed:#x}, size {size}): {msg}\ncase: {case:#?}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert-equality helper for property bodies.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({a:?} vs {b:?})",
                stringify!($a),
                stringify!($b)
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut count = 0;
        forall(
            Config {
                iters: 50,
                ..Default::default()
            },
            |rng| rng.range(0, 100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            Config::default(),
            |rng| rng.range(0, 10),
            |&x| {
                if x < 9 {
                    Ok(())
                } else {
                    Err("hit nine".into())
                }
            },
        );
    }

    #[test]
    fn sized_generation_grows() {
        let mut max_size_seen = 0;
        forall_sized(
            Config {
                iters: 64,
                ..Default::default()
            },
            |_rng, size| size,
            |&s| {
                max_size_seen = max_size_seen.max(s);
                Ok(())
            },
        );
        assert!(max_size_seen >= 32);
    }

    #[test]
    fn prop_macros_compile() {
        let check = || -> PropResult {
            prop_assert!(1 + 1 == 2, "math broke");
            prop_assert_eq!(2 + 2, 4);
            Ok(())
        };
        assert!(check().is_ok());
    }
}
