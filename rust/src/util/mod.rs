//! Shared utilities built from scratch for the offline environment:
//! a deterministic PRNG, a property-testing mini-framework, and an
//! fd-rlimit shim for the connection soaks.

pub mod fdlimit;
pub mod propcheck;
pub mod rng;

/// Integer square root (floor). Used by the `~√N` section-size heuristics
/// of the paper's global operations (§7.4, §7.7).
pub fn isqrt(n: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    let mut x = (n as f64).sqrt() as u64;
    // Newton touch-up against float error.
    while (x + 1) * (x + 1) <= n {
        x += 1;
    }
    while x * x > n {
        x -= 1;
    }
    x
}

/// Integer cube root (floor). Used by the 2-D sum section sizing
/// `Mx ~ My ~ ∛(Nx·Ny)` (§7.4).
pub fn icbrt(n: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    let mut x = (n as f64).cbrt() as u64;
    while (x + 1) * (x + 1) * (x + 1) <= n {
        x += 1;
    }
    while x * x * x > n {
        x -= 1;
    }
    x
}

/// Ceiling division.
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isqrt_exact_and_floor() {
        assert_eq!(isqrt(0), 0);
        assert_eq!(isqrt(1), 1);
        assert_eq!(isqrt(15), 3);
        assert_eq!(isqrt(16), 4);
        assert_eq!(isqrt(17), 4);
        assert_eq!(isqrt(1 << 40), 1 << 20);
        for n in 0..2000u64 {
            let r = isqrt(n);
            assert!(r * r <= n && (r + 1) * (r + 1) > n, "n={n}");
        }
    }

    #[test]
    fn icbrt_exact_and_floor() {
        assert_eq!(icbrt(0), 0);
        assert_eq!(icbrt(7), 1);
        assert_eq!(icbrt(8), 2);
        assert_eq!(icbrt(26), 2);
        assert_eq!(icbrt(27), 3);
        for n in 0..2000u64 {
            let r = icbrt(n);
            assert!(r * r * r <= n && (r + 1) * (r + 1) * (r + 1) > n, "n={n}");
        }
    }

    #[test]
    fn div_ceil_cases() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 8), 1);
    }
}
