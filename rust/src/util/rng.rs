//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**).
//!
//! The offline crate set has no `rand`, so the library carries its own
//! generator: xoshiro256** (Blackman & Vigna), seeded through SplitMix64 —
//! the standard construction. Deterministic by seed; used by tests,
//! benchmarks and workload generators.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction (never all-zero internal state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection method.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let low = m as u64;
            if low >= bound && low < bound.wrapping_neg() {
                // fast accept once low can no longer bias
            }
            if low >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range empty");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform i32 over the full range.
    pub fn i32(&mut self) -> i32 {
        self.next_u64() as u32 as i32
    }

    /// Uniform i32 in `[lo, hi)`.
    pub fn i32_range(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo < hi);
        lo + self.below((hi as i64 - lo as i64) as u64) as i64 as i32
    }

    /// Bernoulli(1/2).
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of uniform i32 in `[lo, hi)`.
    pub fn vec_i32(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.i32_range(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_hits_every_value() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..500 {
            seen[r.range(0, 8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn i32_range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..500 {
            let v = r.i32_range(-5, 6);
            assert!((-5..6).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..500 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::new(13);
        let mut buckets = [0usize; 10];
        for _ in 0..10_000 {
            buckets[r.range(0, 10)] += 1;
        }
        for &b in &buckets {
            assert!((700..1300).contains(&b), "bucket {b} out of tolerance");
        }
    }
}
